//! Sparsity controller: the per-step routing decision the scheduler
//! executes.
//!
//! `Mode` maps a mode string to the family of compiled decode entries
//! (`polar` = SHA head/group sparsity at the model's critical density,
//! Table 1, plus calibrated dynamic MLP top-k for ReLU models; `dejavu` =
//! the MLP-only baseline §5.2; `dense` disables sparsity).
//!
//! The controller is consulted **every decode step**: [`SparsityController::plan`]
//! runs the artifact's routers ([`RouterBank`]) on the step's inputs and
//! returns the entry tag plus the `head_idx`/`mlp_idx` tensors the
//! index-taking `polar` entries consume, while accumulating per-layer
//! union-density telemetry, head-selection histograms and router-overhead
//! time. When the artifact ships no router weights, a `polar` controller
//! degrades gracefully: it logs one warning, counts the steps in
//! `fallback_steps`, and serves the `dense` entries instead of faulting.
//! Density itself is fixed per serving session (the paper fixes top-k per
//! layer too; adaptive per-step density is its future-work §6).

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::runtime::{Manifest, RouterBank, RoutingPolicy, StepRouting};
use crate::substrate::json::Json;

#[derive(Debug, Clone, Copy)]
pub enum Mode {
    Dense,
    DejaVu,
    Polar { density: f64 },
}

/// Mode equality compares via the entry tag, so the `f64`-carrying
/// variant gets a sane story: densities that round to the same compiled
/// entry (3 decimals, e.g. `0.5` vs `0.5000004`) are the same mode.
impl PartialEq for Mode {
    fn eq(&self, other: &Mode) -> bool {
        self.tag() == other.tag()
    }
}

impl Eq for Mode {}

impl Mode {
    pub fn parse(s: &str, critical: f64) -> Result<Mode> {
        match s {
            "dense" => Ok(Mode::Dense),
            "dejavu" => Ok(Mode::DejaVu),
            "polar" => Ok(Mode::Polar { density: critical }),
            other => {
                if let Some(d) = other.strip_prefix("polar@") {
                    let density: f64 = d
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad density in {other:?}"))?;
                    if !density.is_finite() || density <= 0.0 || density > 1.0 {
                        bail!(
                            "density {density} out of range in {other:?} \
                             (need 0 < d <= 1)"
                        );
                    }
                    Ok(Mode::Polar { density })
                } else {
                    bail!("unknown mode {other:?} (dense|dejavu|polar|polar@<d>)")
                }
            }
        }
    }

    pub fn tag(&self) -> String {
        match self {
            Mode::Dense => "dense".to_string(),
            Mode::DejaVu => "dejavu".to_string(),
            Mode::Polar { density } => Manifest::mode_tag("polar", *density),
        }
    }
}

/// One step's plan: which decode entry to run and, for routed modes, the
/// index tensors to feed it.
#[derive(Debug)]
pub struct StepPlan {
    pub tag: String,
    pub routing: Option<StepRouting>,
}

/// Telemetry accumulated across `plan` calls; surfaced in server `stats`
/// and `bench sparsity-scaling`.
#[derive(Debug, Clone, Default)]
pub struct RoutingStats {
    pub steps: u64,
    pub routed_steps: u64,
    /// Steps served by the dense fallback because router weights were
    /// missing from the artifact.
    pub fallback_steps: u64,
    pub router_ns: u64,
    pub n_layers: usize,
    pub n_groups: usize,
    /// Per-layer sums of per-step batch-union head density (mean = sum /
    /// routed_steps).
    pub head_union_sum: Vec<f64>,
    pub mlp_union_sum: Vec<f64>,
    /// Head-selection histogram, [n_layers * n_groups] row-major.
    pub head_counts: Vec<u64>,
    /// Per-request head work density (batch-invariant, = head_k / G).
    pub head_density: f64,
}

impl RoutingStats {
    fn absorb(&mut self, r: &StepRouting) {
        self.routed_steps += 1;
        self.router_ns += r.router_ns;
        self.n_groups = r.n_groups;
        self.head_density = r.head_density();
        if self.head_union_sum.len() != r.head_union.len() {
            self.n_layers = r.head_union.len();
            self.head_union_sum = vec![0.0; r.head_union.len()];
            self.head_counts = vec![0; r.head_counts.len()];
        }
        for (s, u) in self.head_union_sum.iter_mut().zip(&r.head_union) {
            *s += u;
        }
        if self.mlp_union_sum.len() != r.mlp_union.len() {
            self.mlp_union_sum = vec![0.0; r.mlp_union.len()];
        }
        for (s, u) in self.mlp_union_sum.iter_mut().zip(&r.mlp_union) {
            *s += u;
        }
        for (c, n) in self.head_counts.iter_mut().zip(&r.head_counts) {
            *c += n;
        }
    }

    /// Per-layer mean batch-union head density over the routed steps.
    pub fn head_union_mean(&self) -> Vec<f64> {
        let n = self.routed_steps.max(1) as f64;
        self.head_union_sum.iter().map(|s| s / n).collect()
    }

    pub fn mlp_union_mean(&self) -> Vec<f64> {
        let n = self.routed_steps.max(1) as f64;
        self.mlp_union_sum.iter().map(|s| s / n).collect()
    }

    pub fn to_json(&self) -> Json {
        let per_layer = |v: &[f64]| Json::arr(v.iter().map(|&x| x.into()));
        let hist = Json::arr((0..self.n_layers).map(|l| {
            Json::arr(
                self.head_counts[l * self.n_groups..(l + 1) * self.n_groups]
                    .iter()
                    .map(|&c| (c as usize).into()),
            )
        }));
        Json::obj(vec![
            ("steps", (self.steps as usize).into()),
            ("routed_steps", (self.routed_steps as usize).into()),
            ("fallback_steps", (self.fallback_steps as usize).into()),
            ("router_overhead_ms", (self.router_ns as f64 * 1e-6).into()),
            (
                "router_ns_per_step",
                (self.router_ns as f64 / self.routed_steps.max(1) as f64).into(),
            ),
            ("head_density_per_request", self.head_density.into()),
            ("head_union_density", per_layer(&self.head_union_mean())),
            ("mlp_union_density", per_layer(&self.mlp_union_mean())),
            ("head_selection_hist", hist),
        ])
    }
}

/// Consulted each scheduling step; owns the router bank and the routing
/// telemetry.
/// A lazily-initialized shared router bank: pre-set for mock/tests,
/// engine-shared (and built on first routed use) for real artifacts.
type BankCell = Arc<OnceLock<Option<RouterBank>>>;

fn preset_bank(bank: Option<RouterBank>) -> BankCell {
    let cell = OnceLock::new();
    let _ = cell.set(bank);
    Arc::new(cell)
}

#[derive(Debug, Clone)]
pub struct SparsityController {
    mode: Mode,
    routers: BankCell,
    /// Default policy (mock engine / tests, and any batch bucket without
    /// an override).
    policy: RoutingPolicy,
    /// Per-batch-bucket overrides read off the manifest's index-taking
    /// entries: the mlp_idx capacity Km is calibrated per bucket (the
    /// union the entry must gather grows with batch), so each bucket's
    /// steps must be planned with that bucket's own policy or the index
    /// tensor shapes will not match the compiled entry.
    policies_by_batch: BTreeMap<usize, RoutingPolicy>,
    /// Polar was requested but the artifact has no router weights AND the
    /// compiled entries demand indices: serve dense instead of faulting.
    fallback: bool,
    warned: bool,
    pub stats: RoutingStats,
}

impl SparsityController {
    /// Controller without runtime routing: legacy in-graph entries and
    /// the mock engine. Never falls back — the compiled entries of
    /// `mode` are assumed self-contained.
    pub fn new(mode: Mode) -> Self {
        SparsityController {
            mode,
            routers: preset_bank(None),
            policy: RoutingPolicy::default(),
            policies_by_batch: BTreeMap::new(),
            fallback: false,
            warned: false,
            stats: RoutingStats::default(),
        }
    }

    /// Controller with an explicit router bank + policy (mock engine,
    /// benches, tests). Passing `None` for a `Polar` mode means "the
    /// artifact should have routers but does not": the controller falls
    /// back to dense with a warning + metric instead of faulting.
    pub fn with_routers(
        mode: Mode,
        bank: Option<RouterBank>,
        policy: RoutingPolicy,
    ) -> Self {
        let fallback = bank.is_none() && matches!(mode, Mode::Polar { .. });
        SparsityController {
            mode,
            routers: preset_bank(bank),
            policy,
            policies_by_batch: BTreeMap::new(),
            fallback,
            warned: false,
            stats: RoutingStats::default(),
        }
    }

    /// Controller for a real artifact: share the engine-loaded router
    /// bank and read one policy per batch bucket off the manifest's
    /// index-taking entries (Km is calibrated per bucket). Legacy
    /// manifests (no index inputs anywhere) get a non-routing controller;
    /// index-taking manifests without router weights get the dense
    /// fallback.
    pub fn for_engine(mode: Mode, engine: &crate::runtime::Engine) -> Self {
        let m = engine.exec.manifest();
        let prefix = format!("decode_{}_", mode.tag());
        let mut by_batch: BTreeMap<usize, RoutingPolicy> = BTreeMap::new();
        for e in m
            .entries
            .values()
            .filter(|e| e.kind == "decode" && e.name.starts_with(&prefix))
        {
            if let Some(p) = RoutingPolicy::from_entry(e) {
                by_batch.entry(e.batch()).or_insert(p);
            }
        }
        if by_batch.is_empty() {
            return SparsityController::new(mode); // legacy in-graph entries
        }
        // per-request MLP top-k comes from the smallest bucket's
        // calibration (closest to a single request's activation set);
        // each bucket keeps its own union capacity Km
        let base_req = by_batch.values().next().unwrap().mlp_req_k.clone();
        for p in by_batch.values_mut() {
            if p.mlp_cap > 0 && base_req.len() == p.mlp_req_k.len() {
                p.mlp_req_k =
                    base_req.iter().map(|&k| k.clamp(1, p.mlp_cap)).collect();
            }
        }
        let policy = by_batch.values().next().unwrap().clone();
        // polar forces the (lazy) bank build now so fallback is decided
        // up front; dense/dejavu never touch it (&& short-circuits)
        let fallback =
            matches!(mode, Mode::Polar { .. }) && engine.router_bank().is_none();
        SparsityController {
            mode,
            routers: engine.router_cell(),
            policy,
            policies_by_batch: by_batch,
            fallback,
            warned: false,
            stats: RoutingStats::default(),
        }
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// True when polar was requested but the controller is serving the
    /// dense fallback (router weights missing).
    pub fn is_fallback(&self) -> bool {
        self.fallback
    }

    pub fn decode_tag(&self) -> String {
        if self.fallback {
            "dense".to_string()
        } else {
            self.mode.tag()
        }
    }

    /// The per-step decision: entry tag + router indices for the current
    /// batch (`tokens`/`lengths` per slot, as passed to `decode`).
    /// `active` marks the slots carrying live requests — padding slots
    /// are excluded from selection, capacity and telemetry (`None` =
    /// every slot live). The policy is resolved per batch bucket, since
    /// each bucket's compiled entry declares its own index widths.
    pub fn plan(
        &mut self,
        tokens: &[i32],
        lengths: &[i32],
        active: Option<&[bool]>,
    ) -> Result<StepPlan> {
        self.stats.steps += 1;
        if self.fallback {
            if !self.warned {
                self.warned = true;
                eprintln!(
                    "warning: mode {:?} requested but the artifact has no router \
                     weights; serving dense entries (see stats.sparsity.fallback_steps)",
                    self.mode
                );
            }
            self.stats.fallback_steps += 1;
            return Ok(StepPlan { tag: "dense".to_string(), routing: None });
        }
        let routed = matches!(self.mode, Mode::Polar { .. });
        let bank = self.routers.get().and_then(|b| b.as_ref());
        let routing = match (routed, bank) {
            (true, Some(bank)) => {
                let policy = self
                    .policies_by_batch
                    .get(&tokens.len())
                    .unwrap_or(&self.policy);
                let r = bank.route_step(tokens, lengths, active, policy)?;
                self.stats.absorb(&r);
                Some(r)
            }
            _ => None,
        };
        Ok(StepPlan { tag: self.mode.tag(), routing })
    }

    /// Graceful degradation: the plan to run *instead* when the polar
    /// (or dejavu) step faulted — the dense fallback entries, which
    /// `validate` guarantees exist at every bucket whenever a routed
    /// variant is served. Counted in `fallback_steps` alongside the
    /// missing-router-weights fallback: both are "a routed step served
    /// dense", just with different triggers.
    pub fn degrade(&mut self) -> StepPlan {
        self.stats.fallback_steps += 1;
        StepPlan { tag: "dense".to_string(), routing: None }
    }

    /// Check the manifest actually has the chosen variant at every
    /// (batch, seq) bucket — plus the `dense` entries the controller
    /// falls back to — so the scheduler never faults mid-flight.
    pub fn validate(&self, m: &Manifest) -> Result<()> {
        let mut tags = vec![self.decode_tag()];
        if tags[0] != "dense" {
            tags.push("dense".to_string()); // graceful-degradation target
        }
        for tag in &tags {
            for &b in &m.batch_buckets {
                for &n in &m.seq_buckets {
                    let name = m.decode_entry_name(tag, b, n);
                    if m.entries.get(&name).is_none() {
                        bail!("manifest missing {name} (mode {:?})", self.mode);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RouterBank;

    #[test]
    fn parse_modes() {
        assert_eq!(Mode::parse("dense", 0.5).unwrap(), Mode::Dense);
        assert_eq!(Mode::parse("dejavu", 0.5).unwrap(), Mode::DejaVu);
        assert_eq!(
            Mode::parse("polar", 0.25).unwrap(),
            Mode::Polar { density: 0.25 }
        );
        assert_eq!(
            Mode::parse("polar@0.625", 0.5).unwrap(),
            Mode::Polar { density: 0.625 }
        );
        assert!(Mode::parse("nope", 0.5).is_err());
    }

    #[test]
    fn parse_rejects_out_of_range_density() {
        for bad in ["polar@0", "polar@-0.5", "polar@1.5", "polar@nan", "polar@inf"] {
            let e = Mode::parse(bad, 0.5);
            assert!(e.is_err(), "{bad} parsed");
            let msg = format!("{:#}", e.unwrap_err());
            assert!(
                msg.contains("out of range") || msg.contains("bad density"),
                "{bad}: {msg}"
            );
        }
        let e = Mode::parse("polar@2", 0.5).unwrap_err();
        assert!(format!("{e:#}").contains("need 0 < d <= 1"), "{e:#}");
        // the boundary itself is valid
        assert_eq!(
            Mode::parse("polar@1.0", 0.5).unwrap(),
            Mode::Polar { density: 1.0 }
        );
    }

    #[test]
    fn mode_equality_compares_via_tag() {
        // densities rounding to the same compiled entry are equal...
        assert_eq!(
            Mode::Polar { density: 0.5 },
            Mode::Polar { density: 0.5000004 }
        );
        // ...distinct entries are not, and neither are other modes
        assert_ne!(Mode::Polar { density: 0.5 }, Mode::Polar { density: 0.625 });
        assert_ne!(Mode::Polar { density: 1.0 }, Mode::Dense);
        assert_ne!(Mode::Dense, Mode::DejaVu);
    }

    #[test]
    fn tags() {
        assert_eq!(Mode::Dense.tag(), "dense");
        assert_eq!(Mode::Polar { density: 0.5 }.tag(), "polar_d0500");
    }

    fn bank() -> RouterBank {
        // d=2, L=1, G=2: token 1 -> group 0, token 2 -> group 1
        RouterBank::new(
            1,
            2,
            2,
            4,
            1,
            vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0],
            vec![],
            vec![1.0, 0.0, 0.0, 1.0],
            vec![0.0, 0.0],
            None,
        )
        .unwrap()
    }

    #[test]
    fn plan_routes_polar_and_accumulates_stats() {
        let policy = RoutingPolicy { head_k: 1, ..Default::default() };
        let mut ctl = SparsityController::with_routers(
            Mode::Polar { density: 0.5 },
            Some(bank()),
            policy,
        );
        assert!(!ctl.is_fallback());
        let p = ctl.plan(&[1, 2], &[3, 3], None).unwrap();
        assert_eq!(p.tag, "polar_d0500");
        let r = p.routing.expect("routing");
        assert_eq!(r.head_idx.as_i32().unwrap(), &[0, 1]);
        ctl.plan(&[1, 1], &[4, 4], None).unwrap();
        assert_eq!(ctl.stats.routed_steps, 2);
        // step 1 union = 2/2, step 2 union = 1/2 -> mean 0.75
        assert!((ctl.stats.head_union_mean()[0] - 0.75).abs() < 1e-12);
        assert_eq!(ctl.stats.head_counts, vec![3, 1]);
        let j = ctl.stats.to_json();
        assert_eq!(j.get("routed_steps").as_usize(), Some(2));
        assert_eq!(j.get("fallback_steps").as_usize(), Some(0));
    }

    #[test]
    fn dense_mode_plans_without_routing() {
        let mut ctl = SparsityController::new(Mode::Dense);
        let p = ctl.plan(&[1], &[2], None).unwrap();
        assert_eq!(p.tag, "dense");
        assert!(p.routing.is_none());
        assert_eq!(ctl.stats.routed_steps, 0);
    }

    #[test]
    fn missing_routers_fall_back_to_dense_with_metric() {
        let mut ctl = SparsityController::with_routers(
            Mode::Polar { density: 0.5 },
            None,
            RoutingPolicy { head_k: 1, ..Default::default() },
        );
        assert!(ctl.is_fallback());
        assert_eq!(ctl.decode_tag(), "dense");
        for _ in 0..3 {
            let p = ctl.plan(&[1], &[2], None).unwrap();
            assert_eq!(p.tag, "dense");
            assert!(p.routing.is_none());
        }
        assert_eq!(ctl.stats.fallback_steps, 3);
        assert_eq!(
            ctl.stats.to_json().get("fallback_steps").as_usize(),
            Some(3)
        );
    }

    #[test]
    fn legacy_controller_never_falls_back() {
        // `new` models mock/legacy artifacts whose entries are
        // self-contained: polar keeps its tag even without a bank
        let mut ctl = SparsityController::new(Mode::Polar { density: 0.5 });
        assert!(!ctl.is_fallback());
        let p = ctl.plan(&[1], &[2], None).unwrap();
        assert_eq!(p.tag, "polar_d0500");
        assert!(p.routing.is_none());
    }

    #[test]
    fn validate_requires_dense_fallback_entries() {
        // manifest with polar entries but NO dense ones must fail
        let dir = std::env::temp_dir().join("ps_sparsity_validate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "model": "m", "analogue": "x",
          "config": {"d_model": 8, "n_layers": 2, "n_heads": 2, "n_kv_heads": 2,
                     "d_ff": 16, "d_head": 4, "vocab": 10, "max_seq": 32,
                     "mlp": "relu", "pos": "learned", "critical_density": 0.5},
          "params": [],
          "buckets": {"batch": [1], "seq": [16], "prefill": 16},
          "entries": [{"name": "decode_polar_d0500_b1_n16", "kind": "decode",
            "file": "hlo/x.hlo.txt", "data": [], "outputs": [],
            "meta": {"batch": 1, "seq_bucket": 16, "mode": "polar", "density": 0.5}}]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let ctl = SparsityController::new(Mode::Polar { density: 0.5 });
        let e = ctl.validate(&m).unwrap_err();
        assert!(format!("{e:#}").contains("decode_dense_b1_n16"), "{e:#}");
        // dense mode on the same manifest also fails (no dense entries)
        assert!(SparsityController::new(Mode::Dense).validate(&m).is_err());
    }
}
