//! Request/response types, generation parameters, and the per-token
//! generation event stream.

use std::time::{Duration, Instant};

/// Sampling configuration for one request.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 => greedy (argmax).
    pub temperature: f32,
    /// Restrict sampling to the top-k logits (0 => no restriction).
    pub top_k: usize,
    pub max_new_tokens: usize,
    /// Stop when this token id is produced (the corpus line separator '\n'
    /// by default). Negative disables.
    pub stop_token: i32,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            max_new_tokens: 32,
            stop_token: b'\n' as i32,
            seed: 0,
        }
    }
}

/// An admitted generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt_ids: Vec<i32>,
    pub params: SamplingParams,
    /// Admission priority: higher values leave the pending queue first
    /// (FIFO among equals).
    pub priority: i32,
    /// Relative deadline from `enqueued_at`. Expired requests finish with
    /// `FinishReason::Deadline` — active slots stop decoding, pending ones
    /// never start.
    pub deadline: Option<Duration>,
    /// Token-id sequences that terminate generation when the output ends
    /// with one of them (`FinishReason::StopSequence`). The matched
    /// sequence stays in the output.
    pub stop_sequences: Vec<Vec<i32>>,
    pub enqueued_at: Instant,
}

impl Request {
    /// Start building a request from its prompt token ids.
    pub fn builder(prompt_ids: Vec<i32>) -> RequestBuilder {
        RequestBuilder {
            req: Request {
                id: 0,
                prompt_ids,
                params: SamplingParams::default(),
                priority: 0,
                deadline: None,
                stop_sequences: Vec::new(),
                enqueued_at: Instant::now(),
            },
        }
    }
}

/// Builder for [`Request`]; `build()` stamps `enqueued_at`.
#[derive(Debug, Clone)]
pub struct RequestBuilder {
    req: Request,
}

impl RequestBuilder {
    pub fn id(mut self, id: u64) -> Self {
        self.req.id = id;
        self
    }

    pub fn params(mut self, params: SamplingParams) -> Self {
        self.req.params = params;
        self
    }

    pub fn max_new_tokens(mut self, n: usize) -> Self {
        self.req.params.max_new_tokens = n;
        self
    }

    pub fn temperature(mut self, t: f32) -> Self {
        self.req.params.temperature = t;
        self
    }

    pub fn priority(mut self, p: i32) -> Self {
        self.req.priority = p;
        self
    }

    pub fn deadline(mut self, d: Duration) -> Self {
        self.req.deadline = Some(d);
        self
    }

    pub fn stop_sequence(mut self, seq: Vec<i32>) -> Self {
        if !seq.is_empty() {
            self.req.stop_sequences.push(seq);
        }
        self
    }

    pub fn build(mut self) -> Request {
        self.req.enqueued_at = Instant::now();
        self.req
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Stop,
    Length,
    CacheLimit,
    /// Output ended with one of the request's stop sequences.
    StopSequence,
    /// Reaped by `Scheduler::cancel`.
    Cancelled,
    /// The request's relative deadline expired before it finished.
    Deadline,
    /// The prompt exceeds the largest seq bucket; rejected instead of
    /// silently truncated (the server surfaces this as the
    /// `prompt_too_long` protocol error before a slot is burned).
    PromptTooLong,
    /// Turned away by the admission controller under block-pool pressure
    /// (`PressurePolicy::Reject`): predicted KV demand did not fit the
    /// unreserved free pool and preemption could not make room.
    Rejected,
    /// The engine persistently failed on this request (blame isolation
    /// pinned it) or its logits went non-finite (sampler quarantine).
    /// Partial output is preserved; every co-batched request keeps
    /// streaming.
    EngineFault,
}

impl FinishReason {
    /// Wire-protocol string (PROTOCOL.md `finish` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::CacheLimit => "cache_limit",
            FinishReason::StopSequence => "stop_sequence",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Deadline => "deadline",
            FinishReason::PromptTooLong => "prompt_too_long",
            FinishReason::Rejected => "rejected",
            FinishReason::EngineFault => "engine_fault",
        }
    }
}

/// Completed generation, with per-request latency breakdown.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub output_ids: Vec<i32>,
    pub finish: FinishReason,
    pub prompt_len: usize,
    /// Prompt tokens whose KV was served from the shared prefix cache —
    /// their prefill chunks were never computed for this request.
    pub cached_prompt_tokens: usize,
    /// queue-entry -> first token, measured when the token was emitted
    /// (equals `e2e_s` for requests that never produced a token)
    pub ttft_s: f64,
    /// queue-entry -> completion
    pub e2e_s: f64,
    pub decode_steps: usize,
}

/// One item of the scheduler's per-step event stream. Every request
/// produces `Queued`, then (unless it dies in the queue) `Prefilled`,
/// one `Token` per generated token, and exactly one terminal event
/// (`Finished` or `Cancelled`).
#[derive(Debug, Clone)]
pub enum GenerationEvent {
    /// Accepted into the pending queue.
    Queued { request: u64 },
    /// Prompt prefilled into a batch slot; decoding starts this step.
    Prefilled { request: u64 },
    /// One generated token. `index` counts from 0; `text_offset` is the
    /// byte offset in the decoded output text where this token's text
    /// begins (specials contribute no bytes).
    Token {
        request: u64,
        id: i32,
        index: usize,
        text_offset: usize,
    },
    /// Preempted under block-pool pressure: its KV blocks were freed and
    /// it re-entered the queue. Not terminal — the request resumes later
    /// and its token stream continues where it left off.
    Preempted { request: u64 },
    /// This step ran on the dense fallback entries because the polar
    /// path faulted (graceful degradation). Not terminal — tokens keep
    /// flowing, at dense cost.
    Degraded { request: u64 },
    /// Terminal: the request ran to a natural finish (or its deadline).
    Finished(Completion),
    /// Terminal: the request was cancelled; partial output inside.
    Cancelled(Completion),
}

impl GenerationEvent {
    pub fn request_id(&self) -> u64 {
        match self {
            GenerationEvent::Queued { request }
            | GenerationEvent::Prefilled { request }
            | GenerationEvent::Preempted { request }
            | GenerationEvent::Degraded { request }
            | GenerationEvent::Token { request, .. } => *request,
            GenerationEvent::Finished(c) | GenerationEvent::Cancelled(c) => c.id,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            GenerationEvent::Finished(_) | GenerationEvent::Cancelled(_)
        )
    }

    /// Terminal payload, if any.
    pub fn completion(self) -> Option<Completion> {
        match self {
            GenerationEvent::Finished(c) | GenerationEvent::Cancelled(c) => Some(c),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_overrides() {
        let r = Request::builder(vec![1, 2, 3])
            .id(7)
            .max_new_tokens(5)
            .temperature(0.5)
            .priority(2)
            .deadline(Duration::from_millis(100))
            .stop_sequence(vec![10, 11])
            .stop_sequence(vec![]) // ignored
            .build();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt_ids, vec![1, 2, 3]);
        assert_eq!(r.params.max_new_tokens, 5);
        assert_eq!(r.priority, 2);
        assert_eq!(r.deadline, Some(Duration::from_millis(100)));
        assert_eq!(r.stop_sequences, vec![vec![10, 11]]);
    }

    #[test]
    fn event_accessors() {
        let c = Completion {
            id: 3,
            output_ids: vec![1],
            finish: FinishReason::Stop,
            prompt_len: 2,
            cached_prompt_tokens: 0,
            ttft_s: 0.0,
            e2e_s: 0.0,
            decode_steps: 1,
        };
        let ev = GenerationEvent::Finished(c.clone());
        assert_eq!(ev.request_id(), 3);
        assert!(ev.is_terminal());
        assert!(ev.completion().is_some());
        let tok = GenerationEvent::Token { request: 9, id: 65, index: 0, text_offset: 0 };
        assert_eq!(tok.request_id(), 9);
        assert!(!tok.is_terminal());
        assert!(tok.completion().is_none());
    }

    #[test]
    fn finish_reason_strings() {
        assert_eq!(FinishReason::Stop.as_str(), "stop");
        assert_eq!(FinishReason::StopSequence.as_str(), "stop_sequence");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
        assert_eq!(FinishReason::Deadline.as_str(), "deadline");
        assert_eq!(FinishReason::PromptTooLong.as_str(), "prompt_too_long");
        assert_eq!(FinishReason::Rejected.as_str(), "rejected");
        assert_eq!(FinishReason::EngineFault.as_str(), "engine_fault");
    }

    #[test]
    fn preempted_event_is_not_terminal() {
        let ev = GenerationEvent::Preempted { request: 4 };
        assert_eq!(ev.request_id(), 4);
        assert!(!ev.is_terminal());
        assert!(ev.completion().is_none());
    }

    #[test]
    fn degraded_event_is_not_terminal() {
        let ev = GenerationEvent::Degraded { request: 6 };
        assert_eq!(ev.request_id(), 6);
        assert!(!ev.is_terminal());
        assert!(ev.completion().is_none());
    }
}
