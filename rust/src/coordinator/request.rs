//! Request/response types and generation parameters.

use std::time::Instant;

/// Sampling configuration for one request.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 => greedy (argmax).
    pub temperature: f32,
    /// Restrict sampling to the top-k logits (0 => no restriction).
    pub top_k: usize,
    pub max_new_tokens: usize,
    /// Stop when this token id is produced (the corpus line separator '\n'
    /// by default). Negative disables.
    pub stop_token: i32,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            max_new_tokens: 32,
            stop_token: b'\n' as i32,
            seed: 0,
        }
    }
}

/// An admitted generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt_ids: Vec<i32>,
    pub params: SamplingParams,
    pub enqueued_at: Instant,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Stop,
    Length,
    CacheLimit,
}

/// Completed generation, with per-request latency breakdown.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub output_ids: Vec<i32>,
    pub finish: FinishReason,
    pub prompt_len: usize,
    /// queue-entry -> first token
    pub ttft_s: f64,
    /// queue-entry -> completion
    pub e2e_s: f64,
    pub decode_steps: usize,
}
