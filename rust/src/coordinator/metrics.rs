//! Engine + per-request serving metrics.

use std::time::Duration;

use crate::runtime::StepProfile;
use crate::substrate::json::Json;
use crate::substrate::stats::Samples;

#[derive(Debug, Default)]
pub struct EngineMetrics {
    /// Wall time of each decode step (all slots).
    pub step_latency: Samples,
    /// Per-chunk prefill compute: wall time of each chunked-prefill
    /// engine call (the old whole-prompt `prefill_latency` split into its
    /// chunk pieces).
    pub prefill_chunk_latency: Samples,
    /// Queue wait per request: enqueue -> its first prefill chunk starts.
    pub prefill_queue_wait: Samples,
    /// First chunk start -> last chunk done per request (the prompt's
    /// streaming span across interleaved steps).
    pub prefill_chunk_span: Samples,
    /// Last chunk done -> first token emitted (sampling overhead).
    pub prefill_emit_gap: Samples,
    /// Inter-token latency samples, measured between consecutive real
    /// token emissions per slot (pushed by the scheduler's event loop).
    pub itl: Samples,
    /// Time-to-first-token per request, measured when the first token is
    /// actually emitted out of prefill (not back-computed at completion).
    pub ttft: Samples,
    /// End-to-end per request.
    pub e2e: Samples,
    pub decode_steps: u64,
    /// Scheduler iterations (chunked prefill and decode share a step).
    pub sched_steps: u64,
    /// Chunked-prefill engine calls / prompt tokens they consumed.
    pub prefill_chunks: u64,
    pub prefill_tokens: u64,
    /// Steps that ran at least one prefill chunk, and the subset that
    /// also ran a decode batch (the interleaving the chunked path buys).
    pub prefill_steps: u64,
    pub interleaved_steps: u64,
    pub generated_tokens: u64,
    /// Requests that reached a natural terminal (stop / length / cache
    /// limit / stop sequence). Cancellations and deadline expiries are
    /// counted separately below.
    pub completed_requests: u64,
    pub cancelled_requests: u64,
    pub deadline_expired: u64,
    /// Prompts rejected as longer than the largest seq bucket
    /// (`prompt_too_long` — the old path silently truncated these).
    pub rejected_prompts: u64,
    /// Requests turned away by the admission controller under block-pool
    /// pressure (`PressurePolicy::Reject`).
    pub admission_rejections: u64,
    /// Running requests preempted (KV blocks freed, re-queued) and the
    /// subset that later resumed decoding.
    pub preemptions: u64,
    pub resumes: u64,
    /// KV bytes moved to/from host memory by the preemption swap path.
    pub swap_out_bytes: u64,
    pub swap_in_bytes: u64,
    /// Output tokens of requests that finished within their deadline
    /// (requests without a deadline always count; deadline-expired,
    /// cancelled, and rejected requests contribute nothing). The
    /// numerator of `goodput()`.
    pub deadline_met_tokens: u64,
    // -- fault tolerance (the server's `stats.faults` object) ----------
    /// Engine calls retried in place after a transient fault.
    pub transient_retries: u64,
    /// Total milliseconds slept in retry backoff.
    pub backoff_ms: f64,
    /// Blame-isolation searches run (a step kept failing after retries;
    /// batch halves were probed to pin the poisoned request).
    pub blame_bisections: u64,
    /// Requests finished `engine_fault` by blame isolation.
    pub blamed_requests: u64,
    /// Slots quarantined by the sampler's non-finite-logits guard.
    pub quarantined: u64,
    /// Steps that fell back from the polar plan to the dense entries
    /// after a fault (the graceful-degradation path; also counted in
    /// `RoutingStats::fallback_steps`).
    pub degraded_steps: u64,
    /// Engine calls slower than the watchdog threshold.
    pub watchdog_stalls: u64,
    /// Logical seq-bucket growth events. Under paged KV a "promotion" is
    /// a table-width change (different entry next step) — zero cache
    /// bytes move; the counter survives as telemetry of entry switches.
    pub bucket_promotions: u64,
    /// Prompt tokens served straight from the prefix cache instead of
    /// being prefilled (summed over admissions; the per-request figure is
    /// `Completion::cached_prompt_tokens`).
    pub prefix_tokens_skipped: u64,
    /// Host-side KV work wall time (pool creation + copy-on-write block
    /// copies; also in `surgery.host_surgery_ns`).
    pub host_surgery_s: f64,
    /// Scheduler-side contribution to the step breakdown (surgery time +
    /// resident-cache materialization bytes); merged with the engine's
    /// profile by `Scheduler::profile()`.
    pub surgery: StepProfile,
    pub decode_wall_s: f64,
    pub total_wall_s: f64,
}

impl EngineMetrics {
    pub fn record_step(&mut self, d: Duration, active: usize) {
        self.step_latency.push_duration(d);
        self.decode_steps += 1;
        self.decode_wall_s += d.as_secs_f64();
        self.generated_tokens += active as u64;
    }

    /// Decode throughput in generated tokens / second of decode wall time.
    pub fn decode_throughput(&self) -> f64 {
        if self.decode_wall_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.decode_wall_s
    }

    /// Overall throughput incl. prefill + scheduling overheads.
    pub fn total_throughput(&self) -> f64 {
        if self.total_wall_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.total_wall_s
    }

    /// Goodput: deadline-met output tokens / second of total wall time —
    /// the SLO-aware figure the overload bench gates on (ROADMAP item 4).
    pub fn goodput(&self) -> f64 {
        if self.total_wall_s <= 0.0 {
            return 0.0;
        }
        self.deadline_met_tokens as f64 / self.total_wall_s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("decode_steps", (self.decode_steps as usize).into()),
            ("generated_tokens", (self.generated_tokens as usize).into()),
            ("completed_requests", (self.completed_requests as usize).into()),
            ("cancelled_requests", (self.cancelled_requests as usize).into()),
            ("deadline_expired", (self.deadline_expired as usize).into()),
            ("rejected_prompts", (self.rejected_prompts as usize).into()),
            ("decode_tok_per_s", self.decode_throughput().into()),
            ("total_tok_per_s", self.total_throughput().into()),
            ("step_ms_p50", (self.step_latency.p50() * 1e3).into()),
            ("step_ms_p99", (self.step_latency.p99() * 1e3).into()),
            ("itl_ms_mean", (self.itl.mean() * 1e3).into()),
            ("ttft_ms_p50", (self.ttft.p50() * 1e3).into()),
            ("e2e_ms_p50", (self.e2e.p50() * 1e3).into()),
            // The contiguous-era rebuild/surgery counters (kv_rebuilds,
            // regroups, slot_copies, kv_pool_reuses, kv_pool_allocs) were
            // deprecated-at-zero for one release and are now gone — read
            // `stats.kv` instead (PROTOCOL.md "KV memory").
            ("bucket_promotions", (self.bucket_promotions as usize).into()),
            (
                "prefix_tokens_skipped",
                (self.prefix_tokens_skipped as usize).into(),
            ),
            ("host_surgery_ms", (self.host_surgery_s * 1e3).into()),
        ])
    }

    /// The overload-control counters (the core of the server's
    /// `stats.overload` object; the scheduler adds live gauges on top).
    pub fn overload_json(&self) -> Json {
        Json::obj(vec![
            ("preemptions", (self.preemptions as usize).into()),
            ("resumes", (self.resumes as usize).into()),
            ("swap_out_bytes", (self.swap_out_bytes as usize).into()),
            ("swap_in_bytes", (self.swap_in_bytes as usize).into()),
            (
                "admission_rejections",
                (self.admission_rejections as usize).into(),
            ),
            ("deadline_misses", (self.deadline_expired as usize).into()),
            (
                "deadline_met_tokens",
                (self.deadline_met_tokens as usize).into(),
            ),
            ("goodput_tok_per_s", self.goodput().into()),
        ])
    }

    /// The fault-tolerance counters (the server's `stats.faults` object).
    pub fn faults_json(&self) -> Json {
        Json::obj(vec![
            ("transient_retries", (self.transient_retries as usize).into()),
            ("backoff_ms", self.backoff_ms.into()),
            ("blame_bisections", (self.blame_bisections as usize).into()),
            ("blamed_requests", (self.blamed_requests as usize).into()),
            ("quarantined", (self.quarantined as usize).into()),
            ("degraded_steps", (self.degraded_steps as usize).into()),
            ("watchdog_stalls", (self.watchdog_stalls as usize).into()),
        ])
    }

    /// Serving metrics plus a step-cost breakdown under `"step_profile"`.
    /// Pass the ALREADY-merged profile — `Scheduler::profile()` is the
    /// single place engine transfers/compute and scheduler surgery are
    /// combined; this method does no merging of its own.
    pub fn to_json_with_profile(&self, profile: &StepProfile) -> Json {
        let mut j = self.to_json();
        j.set("step_profile", profile.to_json());
        j
    }

    /// The server's `stats.prefill` object: chunked-prefill counters, the
    /// interleave ratio (prefill steps that also decoded), the per-chunk
    /// compute / queue-wait latency series and the TTFT breakdown
    /// (queued -> first chunk -> last chunk -> first token).
    /// `queued_prompt_tokens` is the live gauge the scheduler computes.
    pub fn prefill_json(&self, queued_prompt_tokens: usize) -> Json {
        let interleave = if self.prefill_steps == 0 {
            0.0
        } else {
            self.interleaved_steps as f64 / self.prefill_steps as f64
        };
        let chunks_per_step = if self.sched_steps == 0 {
            0.0
        } else {
            self.prefill_chunks as f64 / self.sched_steps as f64
        };
        Json::obj(vec![
            ("chunks", (self.prefill_chunks as usize).into()),
            ("tokens", (self.prefill_tokens as usize).into()),
            ("chunks_per_step", chunks_per_step.into()),
            ("interleave_ratio", interleave.into()),
            ("queued_prompt_tokens", queued_prompt_tokens.into()),
            ("chunk_ms_p50", (self.prefill_chunk_latency.p50() * 1e3).into()),
            ("chunk_ms_p99", (self.prefill_chunk_latency.p99() * 1e3).into()),
            ("queue_wait_ms_p50", (self.prefill_queue_wait.p50() * 1e3).into()),
            (
                "ttft_breakdown",
                Json::obj(vec![
                    (
                        "queued_to_first_chunk_ms_p50",
                        (self.prefill_queue_wait.p50() * 1e3).into(),
                    ),
                    (
                        "first_to_last_chunk_ms_p50",
                        (self.prefill_chunk_span.p50() * 1e3).into(),
                    ),
                    (
                        "last_chunk_to_first_token_ms_p50",
                        (self.prefill_emit_gap.p50() * 1e3).into(),
                    ),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_active_slots() {
        let mut m = EngineMetrics::default();
        m.record_step(Duration::from_millis(10), 4);
        m.record_step(Duration::from_millis(10), 4);
        assert_eq!(m.generated_tokens, 8);
        assert!((m.decode_throughput() - 400.0).abs() < 1.0);
    }

    #[test]
    fn deprecated_rebuild_keys_are_gone() {
        // the contiguous-era keys shipped as deprecated-at-zero for one
        // release; they must no longer appear — PROTOCOL.md notes removal
        let mut m = EngineMetrics::default();
        m.prefix_tokens_skipped = 256;
        m.bucket_promotions = 2;
        let j = m.to_json();
        for key in ["kv_rebuilds", "regroups", "slot_copies", "kv_pool_reuses", "kv_pool_allocs"]
        {
            assert_eq!(j.get(key).as_usize(), None, "{key} should be removed");
        }
        assert_eq!(j.get("prefix_tokens_skipped").as_usize(), Some(256));
        assert_eq!(j.get("bucket_promotions").as_usize(), Some(2));
    }

    #[test]
    fn overload_json_reports_goodput_and_counters() {
        let mut m = EngineMetrics::default();
        m.preemptions = 3;
        m.resumes = 2;
        m.swap_out_bytes = 4096;
        m.swap_in_bytes = 2048;
        m.admission_rejections = 5;
        m.deadline_expired = 1;
        m.deadline_met_tokens = 120;
        m.total_wall_s = 2.0;
        let j = m.overload_json();
        assert_eq!(j.get("preemptions").as_usize(), Some(3));
        assert_eq!(j.get("resumes").as_usize(), Some(2));
        assert_eq!(j.get("swap_out_bytes").as_usize(), Some(4096));
        assert_eq!(j.get("swap_in_bytes").as_usize(), Some(2048));
        assert_eq!(j.get("admission_rejections").as_usize(), Some(5));
        assert_eq!(j.get("deadline_misses").as_usize(), Some(1));
        assert_eq!(j.get("deadline_met_tokens").as_usize(), Some(120));
        assert_eq!(j.get("goodput_tok_per_s").as_f64(), Some(60.0));
        assert!((m.goodput() - 60.0).abs() < 1e-9);
    }

    #[test]
    fn faults_json_reports_all_counters() {
        let mut m = EngineMetrics::default();
        m.transient_retries = 4;
        m.backoff_ms = 14.0;
        m.blame_bisections = 1;
        m.blamed_requests = 1;
        m.quarantined = 2;
        m.degraded_steps = 3;
        m.watchdog_stalls = 1;
        let j = m.faults_json();
        assert_eq!(j.get("transient_retries").as_usize(), Some(4));
        assert_eq!(j.get("backoff_ms").as_f64(), Some(14.0));
        assert_eq!(j.get("blame_bisections").as_usize(), Some(1));
        assert_eq!(j.get("blamed_requests").as_usize(), Some(1));
        assert_eq!(j.get("quarantined").as_usize(), Some(2));
        assert_eq!(j.get("degraded_steps").as_usize(), Some(3));
        assert_eq!(j.get("watchdog_stalls").as_usize(), Some(1));
    }

    #[test]
    fn prefill_json_reports_breakdown_and_ratios() {
        let mut m = EngineMetrics::default();
        m.sched_steps = 10;
        m.prefill_chunks = 5;
        m.prefill_tokens = 70;
        m.prefill_steps = 4;
        m.interleaved_steps = 3;
        m.prefill_queue_wait.push(0.002);
        m.prefill_chunk_span.push(0.008);
        m.prefill_emit_gap.push(0.0001);
        m.prefill_chunk_latency.push(0.001);
        let j = m.prefill_json(123);
        assert_eq!(j.get("chunks").as_usize(), Some(5));
        assert_eq!(j.get("tokens").as_usize(), Some(70));
        assert_eq!(j.get("queued_prompt_tokens").as_usize(), Some(123));
        assert_eq!(j.get("chunks_per_step").as_f64(), Some(0.5));
        assert_eq!(j.get("interleave_ratio").as_f64(), Some(0.75));
        let b = j.get("ttft_breakdown");
        assert_eq!(b.get("queued_to_first_chunk_ms_p50").as_f64(), Some(2.0));
        assert_eq!(b.get("first_to_last_chunk_ms_p50").as_f64(), Some(8.0));
        assert!(b.get("last_chunk_to_first_token_ms_p50").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn profile_json_embeds_step_profile_verbatim() {
        let m = EngineMetrics::default();
        let p = StepProfile {
            h2d_bytes: 100,
            host_surgery_ns: 2_000_000,
            decode_steps: 1,
            ..Default::default()
        };
        let j = m.to_json_with_profile(&p);
        let sp = j.get("step_profile");
        assert_eq!(sp.get("h2d_bytes_per_step").as_f64(), Some(100.0));
        assert_eq!(sp.get("host_surgery_ms").as_f64(), Some(2.0));
    }
}
