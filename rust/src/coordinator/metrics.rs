//! Engine + per-request serving metrics.

use std::time::Duration;

use crate::substrate::json::Json;
use crate::substrate::stats::Samples;

#[derive(Debug, Default)]
pub struct EngineMetrics {
    /// Wall time of each decode step (all slots).
    pub step_latency: Samples,
    /// Wall time of each prefill call.
    pub prefill_latency: Samples,
    /// Inter-token latency samples, measured between consecutive real
    /// token emissions per slot (pushed by the scheduler's event loop).
    pub itl: Samples,
    /// Time-to-first-token per request, measured when the first token is
    /// actually emitted out of prefill (not back-computed at completion).
    pub ttft: Samples,
    /// End-to-end per request.
    pub e2e: Samples,
    pub decode_steps: u64,
    pub generated_tokens: u64,
    /// Requests that reached a natural terminal (stop / length / cache
    /// limit / stop sequence). Cancellations and deadline expiries are
    /// counted separately below.
    pub completed_requests: u64,
    pub cancelled_requests: u64,
    pub deadline_expired: u64,
    pub kv_rebuilds: u64,
    pub bucket_promotions: u64,
    pub decode_wall_s: f64,
    pub total_wall_s: f64,
}

impl EngineMetrics {
    pub fn record_step(&mut self, d: Duration, active: usize) {
        self.step_latency.push_duration(d);
        self.decode_steps += 1;
        self.decode_wall_s += d.as_secs_f64();
        self.generated_tokens += active as u64;
    }

    /// Decode throughput in generated tokens / second of decode wall time.
    pub fn decode_throughput(&self) -> f64 {
        if self.decode_wall_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.decode_wall_s
    }

    /// Overall throughput incl. prefill + scheduling overheads.
    pub fn total_throughput(&self) -> f64 {
        if self.total_wall_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.total_wall_s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("decode_steps", (self.decode_steps as usize).into()),
            ("generated_tokens", (self.generated_tokens as usize).into()),
            ("completed_requests", (self.completed_requests as usize).into()),
            ("cancelled_requests", (self.cancelled_requests as usize).into()),
            ("deadline_expired", (self.deadline_expired as usize).into()),
            ("decode_tok_per_s", self.decode_throughput().into()),
            ("total_tok_per_s", self.total_throughput().into()),
            ("step_ms_p50", (self.step_latency.p50() * 1e3).into()),
            ("step_ms_p99", (self.step_latency.p99() * 1e3).into()),
            ("itl_ms_mean", (self.itl.mean() * 1e3).into()),
            ("ttft_ms_p50", (self.ttft.p50() * 1e3).into()),
            ("e2e_ms_p50", (self.e2e.p50() * 1e3).into()),
            ("kv_rebuilds", (self.kv_rebuilds as usize).into()),
            ("bucket_promotions", (self.bucket_promotions as usize).into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_active_slots() {
        let mut m = EngineMetrics::default();
        m.record_step(Duration::from_millis(10), 4);
        m.record_step(Duration::from_millis(10), 4);
        assert_eq!(m.generated_tokens, 8);
        assert!((m.decode_throughput() - 400.0).abs() < 1.0);
    }
}
