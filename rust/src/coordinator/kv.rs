//! KV-cache surgery on host tensors.
//!
//! Layout everywhere: `[L, 2, B, G, N, dh]` (layer, k/v, slot, kv-head,
//! position, head dim). The batch group's cache lives as an engine literal
//! on the hot path; these routines run only on composition changes
//! (admission, completion, bucket promotion) and for the PP/TP splits.

use anyhow::{bail, Result};

use crate::runtime::{ModelConfig, Tensor};

/// Shape helper for one sequence's cache (B == 1).
pub fn seq_kv_shape(cfg: &ModelConfig, n: usize) -> Vec<usize> {
    cfg.kv_shape(1, n)
}

fn dims6(t: &Tensor) -> Result<(usize, usize, usize, usize, usize, usize)> {
    let s = t.shape();
    if s.len() != 6 || s[1] != 2 {
        bail!("expected KV shape [L,2,B,G,N,dh], got {:?}", s);
    }
    Ok((s[0], s[1], s[2], s[3], s[4], s[5]))
}

/// Copy one slot out of a batch cache -> [L,2,1,G,N,dh].
pub fn extract_slot(kv: &Tensor, b: usize) -> Result<Tensor> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if b >= bsz {
        bail!("slot {b} out of range (B={bsz})");
    }
    let src = kv.as_f32()?;
    let block = g * n * dh;
    let mut out = vec![0f32; l * two * block];
    for li in 0..l {
        for c in 0..two {
            let s0 = ((li * two + c) * bsz + b) * block;
            let d0 = (li * two + c) * block;
            out[d0..d0 + block].copy_from_slice(&src[s0..s0 + block]);
        }
    }
    Tensor::f32(out, vec![l, two, 1, g, n, dh])
}

/// Write a single-sequence cache (n_src <= n_dst positions) into slot `b`
/// of a batch cache. Extra positions in the destination are zeroed.
pub fn write_slot(kv: &mut Tensor, slot_kv: &Tensor, b: usize) -> Result<()> {
    let (l, two, bsz, g, n_dst, dh) = dims6(kv)?;
    let (l2, _, one, g2, n_src, dh2) = dims6(slot_kv)?;
    if l2 != l || g2 != g || dh2 != dh || one != 1 {
        bail!(
            "slot kv {:?} incompatible with batch kv {:?}",
            slot_kv.shape(),
            kv.shape()
        );
    }
    if n_src > n_dst || b >= bsz {
        bail!("write_slot: n_src {n_src} > n_dst {n_dst} or slot {b} >= {bsz}");
    }
    let src = slot_kv.as_f32()?.to_vec();
    let dst = kv.as_f32_mut()?;
    let row = dh;
    for li in 0..l {
        for c in 0..two {
            for gi in 0..g {
                let dbase = ((((li * two + c) * bsz + b) * g) + gi) * n_dst * row;
                let sbase = ((((li * two + c) * 1) * g) + gi) * n_src * row;
                dst[dbase..dbase + n_src * row]
                    .copy_from_slice(&src[sbase..sbase + n_src * row]);
                for x in &mut dst[dbase + n_src * row..dbase + n_dst * row] {
                    *x = 0.0;
                }
            }
        }
    }
    Ok(())
}

/// Zero a slot (freed sequence) so stale KV never leaks into attention.
pub fn clear_slot(kv: &mut Tensor, b: usize) -> Result<()> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if b >= bsz {
        bail!("slot {b} out of range");
    }
    let dst = kv.as_f32_mut()?;
    let block = g * n * dh;
    for li in 0..l {
        for c in 0..two {
            let d0 = ((li * two + c) * bsz + b) * block;
            for x in &mut dst[d0..d0 + block] {
                *x = 0.0;
            }
        }
    }
    Ok(())
}

/// Grow the position axis to a larger bucket (zero-padded).
pub fn pad_n(kv: &Tensor, n_new: usize) -> Result<Tensor> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if n_new < n {
        bail!("pad_n: {n_new} < current {n}");
    }
    if n_new == n {
        return Ok(kv.clone());
    }
    let src = kv.as_f32()?;
    let mut out = vec![0f32; l * two * bsz * g * n_new * dh];
    let row = dh;
    for li in 0..l {
        for c in 0..two {
            for b in 0..bsz {
                for gi in 0..g {
                    let sbase = ((((li * two + c) * bsz + b) * g) + gi) * n * row;
                    let dbase = ((((li * two + c) * bsz + b) * g) + gi) * n_new * row;
                    out[dbase..dbase + n * row]
                        .copy_from_slice(&src[sbase..sbase + n * row]);
                }
            }
        }
    }
    Tensor::f32(out, vec![l, two, bsz, g, n_new, dh])
}

/// Rebuild a batch cache at a new capacity from per-slot caches.
/// `slots[i] = Some(seq kv [L,2,1,G,n_i,dh])` with n_i <= n_bucket.
pub fn assemble(
    cfg: &ModelConfig,
    slots: &[Option<Tensor>],
    n_bucket: usize,
) -> Result<Tensor> {
    let b = slots.len();
    let mut kv = Tensor::zeros_f32(cfg.kv_shape(b, n_bucket));
    for (i, s) in slots.iter().enumerate() {
        if let Some(t) = s {
            write_slot(&mut kv, t, i)?;
        }
    }
    Ok(kv)
}

/// Split along layers for 2-stage pipeline parallelism.
pub fn split_layers(kv: &Tensor, l0: usize) -> Result<(Tensor, Tensor)> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if l0 == 0 || l0 >= l {
        bail!("split_layers: bad split {l0} of {l}");
    }
    let src = kv.as_f32()?;
    let block = two * bsz * g * n * dh;
    let a = src[..l0 * block].to_vec();
    let b2 = src[l0 * block..].to_vec();
    Ok((
        Tensor::f32(a, vec![l0, two, bsz, g, n, dh])?,
        Tensor::f32(b2, vec![l - l0, two, bsz, g, n, dh])?,
    ))
}

/// Merge two stage caches back (inverse of split_layers).
pub fn merge_layers(kv0: &Tensor, kv1: &Tensor) -> Result<Tensor> {
    let (l0, two, bsz, g, n, dh) = dims6(kv0)?;
    let (l1, ..) = dims6(kv1)?;
    let mut data = kv0.as_f32()?.to_vec();
    data.extend_from_slice(kv1.as_f32()?);
    Tensor::f32(data, vec![l0 + l1, two, bsz, g, n, dh])
}

/// Split into per-shard, per-layer caches for tensor parallelism:
/// result[shard][layer] = [2, B, G/n_shards, N, dh].
pub fn split_groups(kv: &Tensor, n_shards: usize) -> Result<Vec<Vec<Tensor>>> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if g % n_shards != 0 {
        bail!("split_groups: G={g} not divisible by {n_shards}");
    }
    let gs = g / n_shards;
    let src = kv.as_f32()?;
    let mut out = vec![Vec::with_capacity(l); n_shards];
    for s in 0..n_shards {
        for li in 0..l {
            let mut data = Vec::with_capacity(two * bsz * gs * n * dh);
            for c in 0..two {
                for b in 0..bsz {
                    let base = (((li * two + c) * bsz + b) * g + s * gs) * n * dh;
                    data.extend_from_slice(&src[base..base + gs * n * dh]);
                }
            }
            out[s].push(Tensor::f32(data, vec![two, bsz, gs, n, dh])?);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::substrate::prop::check;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            analogue: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 16,
            d_head: 4,
            vocab: 10,
            max_seq: 16,
            mlp: "relu".into(),
            pos: "learned".into(),
            critical_density: 0.5,
        }
    }

    fn filled(shape: Vec<usize>, seed: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::f32((0..n).map(|i| seed + i as f32).collect(), shape).unwrap()
    }

    #[test]
    fn extract_write_roundtrip() {
        let c = cfg();
        let mut kv = filled(c.kv_shape(3, 8), 0.0);
        let slot1 = extract_slot(&kv, 1).unwrap();
        let mut kv2 = Tensor::zeros_f32(c.kv_shape(3, 8));
        write_slot(&mut kv2, &slot1, 1).unwrap();
        let back = extract_slot(&kv2, 1).unwrap();
        assert_eq!(slot1, back);
        // other slots untouched (zero)
        assert!(extract_slot(&kv2, 0).unwrap().as_f32().unwrap().iter().all(|&x| x == 0.0));
        // clear works
        clear_slot(&mut kv, 1).unwrap();
        assert!(extract_slot(&kv, 1).unwrap().as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pad_preserves_prefix() {
        let c = cfg();
        let kv = filled(c.kv_shape(2, 4), 1.0);
        let padded = pad_n(&kv, 8).unwrap();
        assert_eq!(padded.shape(), &[2, 2, 2, 2, 8, 4]);
        // spot check: first row of each (l,c,b,g) group survives
        let s = extract_slot(&kv, 0).unwrap();
        let p = extract_slot(&padded, 0).unwrap();
        let (sn, pn) = (s.as_f32().unwrap(), p.as_f32().unwrap());
        // row 0 of group 0, layer 0, k
        assert_eq!(&sn[0..4], &pn[0..4]);
    }

    #[test]
    fn split_merge_layers_roundtrip() {
        let c = cfg();
        let kv = filled(c.kv_shape(2, 4), 3.0);
        let (a, b) = split_layers(&kv, 1).unwrap();
        assert_eq!(a.shape()[0], 1);
        assert_eq!(b.shape()[0], 1);
        assert_eq!(merge_layers(&a, &b).unwrap(), kv);
    }

    #[test]
    fn split_groups_shapes() {
        let c = cfg();
        let kv = filled(c.kv_shape(2, 4), 0.0);
        let shards = split_groups(&kv, 2).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len(), 2);
        assert_eq!(shards[0][0].shape(), &[2, 2, 1, 4, 4]);
    }

    #[test]
    fn prop_write_then_extract_identity() {
        check("kv-write-extract", 30, |g| {
            let c = cfg();
            let b = g.usize_in(1, 5);
            let n_src = g.usize_in(1, 5);
            let n_dst = g.usize_in(n_src, 9);
            let slot = g.usize_in(0, b);
            let data = g.vec_f32(c.kv_elems(1, n_src), -1.0, 1.0);
            let s = Tensor::f32(data, c.kv_shape(1, n_src)).unwrap();
            let mut kv = Tensor::zeros_f32(c.kv_shape(b, n_dst));
            write_slot(&mut kv, &s, slot).unwrap();
            let out = extract_slot(&kv, slot).unwrap();
            // prefix must match the source; suffix zero
            let padded = pad_n(&s, n_dst).unwrap();
            prop_assert!(out == padded, "slot roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn prop_assemble_no_aliasing() {
        check("kv-assemble", 20, |g| {
            let c = cfg();
            let b = g.usize_in(2, 5);
            let n = 4;
            let slots: Vec<Option<Tensor>> = (0..b)
                .map(|i| {
                    if g.bool() {
                        Some(
                            Tensor::f32(
                                vec![i as f32 + 1.0; c.kv_elems(1, n)],
                                c.kv_shape(1, n),
                            )
                            .unwrap(),
                        )
                    } else {
                        None
                    }
                })
                .collect();
            let kv = assemble(&c, &slots, n).unwrap();
            for (i, s) in slots.iter().enumerate() {
                let got = extract_slot(&kv, i).unwrap();
                match s {
                    Some(t) => prop_assert!(got == *t, "slot {i} clobbered"),
                    None => prop_assert!(
                        got.as_f32().unwrap().iter().all(|&x| x == 0.0),
                        "empty slot {i} non-zero"
                    ),
                }
            }
            Ok(())
        });
    }
}
