// Prototype loader: validates the pallas-SHA-kernel HLO text produced by
// python/proto_sha.py round-trips through the xla crate's PJRT CPU client.
use anyhow::Result;
use xla::FromRawBytes;

fn main() -> Result<()> {
    let path = std::env::args().nth(1).unwrap_or_else(|| "/tmp/sha_hlo.txt".to_string());
    let client = xla::PjRtClient::cpu()?;
    println!("platform={} devices={}", client.platform_name(), client.device_count());

    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let t0 = std::time::Instant::now();
    let exe = client.compile(&comp)?;
    println!("compile: {:?}", t0.elapsed());

    let q = xla::Literal::read_npy("/tmp/sha_q.npy", &())?;
    let k = xla::Literal::read_npy("/tmp/sha_k.npy", &())?;
    let v = xla::Literal::read_npy("/tmp/sha_v.npy", &())?;
    let hi = xla::Literal::vec1(&[0i32, 2, 1, 3]).reshape(&[2, 2])?;
    let ln = xla::Literal::vec1(&[40i32, 64]);

    let t0 = std::time::Instant::now();
    let result = exe.execute::<xla::Literal>(&[hi, ln, q, k, v])?[0][0].to_literal_sync()?;
    println!("execute: {:?}", t0.elapsed());
    let out = result.to_tuple1()?;
    let got = out.to_vec::<f32>()?;

    let expected = xla::Literal::read_npy("/tmp/sha_expected.npy", &())?;
    let want = expected.to_vec::<f32>()?;
    assert_eq!(got.len(), want.len());
    let max_err = got
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("n={} max_err={max_err:.2e}", got.len());
    assert!(max_err < 1e-4, "numerics mismatch");
    println!("proto_load OK");
    Ok(())
}
