//! polar-sparsity CLI: serve / generate / eval / bench / info.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use polar_sparsity::bench;
use polar_sparsity::coordinator::{
    GenerationEvent, Mode, Request, SamplingParams, Scheduler, SchedulerConfig,
    SparsityController,
};
use polar_sparsity::runtime::{Engine, Executor};
use polar_sparsity::server::{serve, Client, ServerConfig};
use polar_sparsity::substrate::argparse::{Args, Parsed};
use polar_sparsity::tokenizer::Tokenizer;

const USAGE: &str = "polar-sparsity — batched LLM serving with scalable contextual sparsity

usage: polar-sparsity <command> [flags]

commands:
  info       print model/manifest summary
  generate   run prompts through the engine locally (--stream for events)
  serve      start the TCP JSON-lines server (protocol v2, PROTOCOL.md)
  client     send a request to a running server (--stream, --cancel-after, --stats)
  eval       zero-shot task-suite accuracy at a sparsity mode
  bench      regenerate a paper figure/table (fig1a..fig14, table1, table2, all),
             `bench decode-breakdown [--smoke]` for the per-step decode
             cost breakdown (BENCH_decode.json),
             `bench sparsity-scaling [--smoke]` for batch-union density
             scaling: head flat vs MLP toward dense (BENCH_sparsity.json), or
             `bench prefill-interference [--smoke]` for chunked-vs-monolithic
             prefill: decoder p99 ITL under long-prompt arrival and TTFT by
             prompt length (BENCH_prefill.json), or
             `bench kv-paging [--smoke]` for the paged KV cache: prefill
             tokens saved by cross-request prefix caching and re-bucket
             bytes vs the contiguous baseline (BENCH_kv.json), or
             `bench overload [--smoke]` for SLO-aware overload control:
             goodput of preemption+admission vs reject-only across
             bursty / heavy-tail / two-tenant / chat-session workloads
             (BENCH_overload.json), or
             `bench fault-recovery [--smoke]` for fault-tolerant stepping:
             replays a trace under injected engine faults and gates that
             every non-poisoned request completes bit-identical to the
             fault-free run (BENCH_faults.json), or
             `bench shard-scaling [--smoke]` for shard-aware serving:
             selective-head routing cuts TP shard dispatches (flat across
             batch) while sharded streams stay bit-identical to
             single-device, zero shell bytes (BENCH_shards.json)

common flags: --model <name> --artifacts <dir> --mode dense|dejavu|polar|polar@<d>
run `polar-sparsity <command> --help` for details";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "info" => cmd_info(rest),
        "generate" => cmd_generate(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "eval" => cmd_eval(rest),
        "bench" if rest.first().map(|s| s.as_str()) == Some("decode-breakdown") => {
            bench::decode_breakdown::run(&rest[1..])
        }
        "bench" if rest.first().map(|s| s.as_str()) == Some("sparsity-scaling") => {
            bench::sparsity_scaling::run(&rest[1..])
        }
        "bench" if rest.first().map(|s| s.as_str()) == Some("prefill-interference") => {
            bench::prefill_interference::run(&rest[1..])
        }
        "bench" if rest.first().map(|s| s.as_str()) == Some("kv-paging") => {
            bench::kv_paging::run(&rest[1..])
        }
        "bench" if rest.first().map(|s| s.as_str()) == Some("overload") => {
            bench::overload::run(&rest[1..])
        }
        "bench" if rest.first().map(|s| s.as_str()) == Some("fault-recovery") => {
            bench::fault_recovery::run(&rest[1..])
        }
        "bench" if rest.first().map(|s| s.as_str()) == Some("shard-scaling") => {
            bench::shard_scaling::run(&rest[1..])
        }
        "bench" => bench::figures::run(rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn common(args: Args) -> Args {
    args.flag("model", "opt-tiny", "model name under the artifacts dir")
        .flag("artifacts", "artifacts", "artifacts root directory")
        .flag("mode", "polar", "dense | dejavu | polar | polar@<density>")
}

fn model_dir(p: &Parsed) -> PathBuf {
    PathBuf::from(p.get("artifacts")).join(p.get("model"))
}

fn parse_or_usage(args: Args, rest: &[String]) -> Parsed {
    match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn load_engine(p: &Parsed) -> Result<(Engine, Mode)> {
    let dir = model_dir(p);
    let exec = Arc::new(Executor::load(&dir).with_context(|| {
        format!("loading {} — run `make artifacts` first", dir.display())
    })?);
    let engine = Engine::new(exec);
    let mode = Mode::parse(p.get("mode"), engine.exec.config().critical_density)?;
    Ok((engine, mode))
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let p = parse_or_usage(common(Args::new("info", "model/manifest summary")), rest);
    let (engine, _) = load_engine(&p)?;
    let m = engine.exec.manifest();
    let c = engine.exec.config();
    println!("model      : {} (analogue of {})", m.model, c.analogue);
    println!(
        "geometry   : d={} L={} H={} H_kv={} d_ff={} mlp={} pos={}",
        c.d_model, c.n_layers, c.n_heads, c.n_kv_heads, c.d_ff, c.mlp, c.pos
    );
    println!("critical attention density: {}", c.critical_density);
    println!(
        "buckets    : batch {:?} seq {:?} prefill chunk {}",
        m.batch_buckets, m.seq_buckets, m.prefill_chunk
    );
    println!("entries    : {}", m.entries.len());
    let mut kinds: std::collections::BTreeMap<&str, usize> = Default::default();
    for e in m.entries.values() {
        *kinds.entry(e.kind.as_str()).or_default() += 1;
    }
    for (k, n) in kinds {
        println!("  {k:<12} {n}");
    }
    Ok(())
}

fn print_completion(tok: &Tokenizer, c: &polar_sparsity::coordinator::Completion) {
    println!(
        "[{}] {:?} ({:?}, {} tokens, ttft {:.1}ms, e2e {:.1}ms)",
        c.id,
        tok.decode(&c.output_ids),
        c.finish,
        c.output_ids.len(),
        c.ttft_s * 1e3,
        c.e2e_s * 1e3
    );
}

fn cmd_generate(rest: &[String]) -> Result<()> {
    let args = common(Args::new("generate", "run prompts locally"))
        .flag("prompt", "copy:abc=", "prompt text (comma-join for several)")
        .flag("max-new", "16", "max new tokens")
        .flag("temperature", "0", "sampling temperature (0 = greedy)")
        .flag("stop", "", "stop sequences, comma-separated text")
        .switch("stream", "print per-token events as they are emitted");
    let p = parse_or_usage(args, rest);
    let (engine, mode) = load_engine(&p)?;
    let ctl = SparsityController::for_engine(mode, &engine);
    ctl.validate(engine.exec.manifest())?;
    let tok = Tokenizer::new();
    let mut sched = Scheduler::new(engine, ctl, SchedulerConfig::default());
    let params = SamplingParams {
        max_new_tokens: p.get_usize("max-new").map_err(anyhow::Error::msg)?,
        temperature: p.get_f64("temperature").map_err(anyhow::Error::msg)? as f32,
        ..Default::default()
    };
    for (i, prompt) in p.get("prompt").split(',').enumerate() {
        let mut b = Request::builder(tok.encode_prompt(prompt))
            .id(i as u64)
            .params(params);
        for stop in p.get_list("stop") {
            b = b.stop_sequence(tok.encode(&stop));
        }
        sched.enqueue(b.build());
    }
    if p.get_bool("stream") {
        // drive the event loop directly, printing tokens as they land
        while !sched.is_idle() {
            for ev in sched.step()? {
                match ev {
                    GenerationEvent::Queued { request } => {
                        println!("[{request}] queued");
                    }
                    GenerationEvent::Prefilled { request } => {
                        println!("[{request}] prefilled");
                    }
                    GenerationEvent::Token { request, id, index, .. } => {
                        println!("[{request}] token {index}: {:?}", tok.decode(&[id]));
                    }
                    GenerationEvent::Preempted { request } => {
                        println!("[{request}] preempted (resumes when blocks free)");
                    }
                    GenerationEvent::Degraded { request } => {
                        println!("[{request}] degraded (routed step fell back to dense)");
                    }
                    GenerationEvent::Finished(c) | GenerationEvent::Cancelled(c) => {
                        print_completion(&tok, &c);
                    }
                }
            }
        }
    } else {
        let mut done = sched.run_to_completion()?;
        done.sort_by_key(|c| c.id);
        for c in &done {
            print_completion(&tok, c);
        }
    }
    println!("\nmetrics: {}", sched.metrics.to_json());
    if sched.sparsity().stats.routed_steps > 0 || sched.sparsity().is_fallback() {
        println!("sparsity: {}", sched.sparsity().stats.to_json());
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let args = common(Args::new("serve", "TCP JSON-lines server"))
        .flag("addr", "127.0.0.1:7878", "bind address")
        .flag("max-batch", "16", "max batch bucket")
        .flag(
            "prefill-chunk-tokens",
            "0",
            "prompt tokens per step spent on prefill chunks (0 = one chunk bucket)",
        );
    let p = parse_or_usage(args, rest);
    let dir = model_dir(&p);
    let manifest = polar_sparsity::runtime::Manifest::load(&dir)?;
    let mode = Mode::parse(p.get("mode"), manifest.config.critical_density)?;
    println!("serving {} ({:?}) on {}", p.get("model"), mode, p.get("addr"));
    serve(
        ServerConfig {
            model_dir: dir,
            addr: p.get("addr").to_string(),
            mode,
            max_batch: p.get_usize("max-batch").map_err(anyhow::Error::msg)?,
            prefill_chunk_tokens: p
                .get_usize("prefill-chunk-tokens")
                .map_err(anyhow::Error::msg)?,
        },
        |addr| println!("listening on {addr}"),
    )
}

fn cmd_client(rest: &[String]) -> Result<()> {
    let args = Args::new("client", "send one request")
        .flag("addr", "127.0.0.1:7878", "server address")
        .flag("prompt", "copy:abc=", "prompt text")
        .flag("max-new", "16", "max new tokens")
        .flag("cancel-after", "0", "with --stream: cancel after N tokens (0 = never)")
        .switch("stream", "stream per-token event lines (protocol v2)")
        .switch("stats", "fetch engine metrics instead")
        .switch("shutdown", "send shutdown instead");
    let p = parse_or_usage(args, rest);
    let mut c = Client::connect(p.get("addr"))?;
    if p.get_bool("shutdown") {
        c.shutdown()?;
        println!("shutdown sent");
        return Ok(());
    }
    if p.get_bool("stats") {
        println!("{}", c.stats()?);
        return Ok(());
    }
    let max_new = p.get_usize("max-new").map_err(anyhow::Error::msg)?;
    if p.get_bool("stream") {
        let cancel_after = p.get_usize("cancel-after").map_err(anyhow::Error::msg)?;
        let mut tokens_seen = 0usize;
        let mut stream = c.stream(p.get("prompt"), max_new)?;
        while let Some(ev) = stream.next() {
            let ev = ev?;
            println!("{ev}");
            if ev.get("event").as_str() == Some("token") {
                tokens_seen += 1;
                if cancel_after > 0 && tokens_seen == cancel_after {
                    stream.cancel()?;
                }
            }
        }
        return Ok(());
    }
    let resp = c.request(p.get("prompt"), max_new)?;
    println!("{resp}");
    Ok(())
}

fn cmd_eval(rest: &[String]) -> Result<()> {
    let args = common(Args::new("eval", "zero-shot task-suite accuracy"))
        .flag("per-family", "20", "items per task family")
        .flag("max-new", "12", "max new tokens per item");
    let p = parse_or_usage(args, rest);
    let (engine, mode) = load_engine(&p)?;
    let suite_path = PathBuf::from(p.get("artifacts")).join("eval_tasks.jsonl");
    let per_family = p.get_usize("per-family").map_err(anyhow::Error::msg)?;
    let max_new = p.get_usize("max-new").map_err(anyhow::Error::msg)?;
    let score =
        bench::accuracy::eval_suite(&engine, mode, &suite_path, per_family, max_new)?;
    for (fam, acc, n) in &score.per_family {
        println!("{fam:<6} {acc:.3}  (n={n})");
    }
    println!("average {:.3}", score.average);
    Ok(())
}
