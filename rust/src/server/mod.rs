//! TCP JSON-lines serving front-end (std::net + threads; the vendored set
//! has no tokio, and a blocking reactor keeps the single-core hot path
//! free of executor overhead).
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "copy:ab=", "max_new": 16, "temperature": 0.0}
//!   <- {"id": 3, "text": "ab", "finish": "stop", "ttft_ms": ..,
//!       "e2e_ms": .., "tokens": [..]}
//!   -> {"cmd": "stats"}   <- engine metrics
//!   -> {"cmd": "shutdown"}
//!
//! Architecture: acceptor + per-connection reader threads push
//! (request, reply-sender) pairs into a shared queue; the engine thread —
//! which owns the (non-Send) PJRT state — drains it, steps the scheduler,
//! and routes completions back.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{
    Completion, Mode, Request, SamplingParams, Scheduler, SchedulerConfig,
    SparsityController,
};
use crate::runtime::{Engine, Executor};
use crate::substrate::json::Json;
use crate::tokenizer::Tokenizer;

pub struct ServerConfig {
    pub model_dir: PathBuf,
    pub addr: String,
    pub mode: Mode,
    pub max_batch: usize,
}

struct Inbound {
    request: Request,
    reply: Sender<Json>,
}

/// Run the server; blocks until a shutdown command arrives.
/// `on_ready` receives the bound address (useful with port 0).
pub fn serve(cfg: ServerConfig, on_ready: impl FnOnce(String)) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr).context("bind")?;
    let local = listener.local_addr()?.to_string();
    let queue: Arc<Mutex<Vec<Inbound>>> = Arc::new(Mutex::new(Vec::new()));
    let shutdown = Arc::new(AtomicBool::new(false));
    let next_id = Arc::new(AtomicU64::new(1));

    // Engine thread owns all PJRT state.
    let q2 = queue.clone();
    let sd2 = shutdown.clone();
    let engine_thread = std::thread::spawn(move || -> Result<()> {
        let exec = Arc::new(Executor::load(&cfg.model_dir)?);
        let engine = Engine::new(exec);
        let ctl = SparsityController::new(cfg.mode);
        ctl.validate(engine.exec.manifest())?;
        let mut sched = Scheduler::new(
            engine,
            ctl,
            SchedulerConfig { max_batch: cfg.max_batch, compact: true },
        );
        let tok = Tokenizer::new();
        let mut waiting: HashMap<u64, Sender<Json>> = HashMap::new();
        loop {
            // drain inbound
            for inb in q2.lock().unwrap().drain(..) {
                waiting.insert(inb.request.id, inb.reply);
                sched.enqueue(inb.request);
            }
            if sched.is_idle() {
                if sd2.load(Ordering::SeqCst) {
                    return Ok(());
                }
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            for c in sched.step()? {
                if let Some(reply) = waiting.remove(&c.id) {
                    let _ = reply.send(completion_json(&tok, &c));
                }
            }
        }
    });

    on_ready(local);

    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let q = queue.clone();
        let sd = shutdown.clone();
        let ni = next_id.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, q, sd, ni);
        });
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    engine_thread
        .join()
        .map_err(|_| anyhow::anyhow!("engine thread panicked"))??;
    Ok(())
}

fn completion_json(tok: &Tokenizer, c: &Completion) -> Json {
    Json::obj(vec![
        ("id", (c.id as usize).into()),
        ("text", tok.decode(&c.output_ids).into()),
        (
            "tokens",
            Json::arr(c.output_ids.iter().map(|&t| (t as i64).into())),
        ),
        (
            "finish",
            match c.finish {
                crate::coordinator::FinishReason::Stop => "stop",
                crate::coordinator::FinishReason::Length => "length",
                crate::coordinator::FinishReason::CacheLimit => "cache_limit",
            }
            .into(),
        ),
        ("ttft_ms", (c.ttft_s * 1e3).into()),
        ("e2e_ms", (c.e2e_s * 1e3).into()),
    ])
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<Mutex<Vec<Inbound>>>,
    shutdown: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
) -> Result<()> {
    let tok = Tokenizer::new();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", Json::obj(vec![("error", e.to_string().into())]))?;
                continue;
            }
        };
        match j.get("cmd").as_str() {
            Some("shutdown") => {
                shutdown.store(true, Ordering::SeqCst);
                // poke the acceptor loop awake
                writeln!(writer, "{}", Json::obj(vec![("ok", true.into())]))?;
                let _ = TcpStream::connect(writer.local_addr()?);
                return Ok(());
            }
            Some("ping") => {
                writeln!(writer, "{}", Json::obj(vec![("ok", true.into())]))?;
                continue;
            }
            _ => {}
        }
        let prompt = j.get("prompt").as_str().unwrap_or("").to_string();
        let params = SamplingParams {
            max_new_tokens: j.get("max_new").as_usize().unwrap_or(32),
            temperature: j.get("temperature").as_f64().unwrap_or(0.0) as f32,
            top_k: j.get("top_k").as_usize().unwrap_or(0),
            ..Default::default()
        };
        let id = next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        queue.lock().unwrap().push(Inbound {
            request: Request {
                id,
                prompt_ids: tok.encode_prompt(&prompt),
                params,
                enqueued_at: Instant::now(),
            },
            reply: tx,
        });
        match rx.recv_timeout(Duration::from_secs(600)) {
            Ok(resp) => writeln!(writer, "{resp}")?,
            Err(_) => writeln!(
                writer,
                "{}",
                Json::obj(vec![("error", "timeout".into()), ("id", (id as usize).into())])
            )?,
        }
    }
    Ok(())
}

/// Minimal blocking client (examples + integration tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, prompt: &str, max_new: usize) -> Result<Json> {
        let j = Json::obj(vec![
            ("prompt", prompt.into()),
            ("max_new", max_new.into()),
        ]);
        writeln!(self.writer, "{j}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(Into::into)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", Json::obj(vec![("cmd", "shutdown".into())]))?;
        let mut line = String::new();
        let _ = self.reader.read_line(&mut line);
        Ok(())
    }
}
