//! TCP JSON-lines serving front-end, protocol v2 (std::net + threads; the
//! vendored set has no tokio, and a blocking reactor keeps the
//! single-core hot path free of executor overhead).
//!
//! See PROTOCOL.md for the full wire specification. One JSON object per
//! line; every server reply is tagged with the server-assigned request
//! `id`, and a connection may hold any number of requests in flight.
//!
//!   -> {"prompt": "copy:ab=", "max_new": 16}
//!   <- {"id": 3, "text": "ab", "finish": "stop", "ttft_ms": ..,
//!       "e2e_ms": .., "tokens": [..]}
//!
//!   -> {"prompt": "copy:ab=", "max_new": 16, "stream": true}
//!   <- {"id": 4, "event": "queued"}
//!   <- {"id": 4, "event": "prefilled"}
//!   <- {"id": 4, "event": "token", "token": 97, "text": "a",
//!       "index": 0, "text_offset": 0}
//!   <- ... one line per token ...
//!   <- {"id": 4, "event": "finished", "text": "ab", "finish": "stop",
//!       "ttft_ms": .., "e2e_ms": .., "tokens": [..]}
//!
//!   -> {"cmd": "cancel", "id": 4}   <- {"ok": true, "id": 4}  (plus the
//!      cancelled request's own terminal {"event": "cancelled", ...} line)
//!   -> {"cmd": "stats"}             <- {"ok": true, "stats": {...}}
//!   -> {"cmd": "ping"}              <- {"ok": true}
//!   -> {"cmd": "shutdown"}
//!
//! Malformed lines and promptless generation requests are rejected with a
//! structured {"error": ..., "id": ...} line and never reach the
//! scheduler; prompts longer than the largest seq bucket are rejected
//! with {"error": "prompt_too_long", "limit": ..., "prompt_len": ...}
//! instead of being truncated, and a request whose deadline has already
//! passed at admission gets {"error": "deadline_expired", "id": ...}
//! without burning a batch slot.
//!
//! Overload control (see PROTOCOL.md "Overload"): streaming requests may
//! see a non-terminal {"event": "preempted"} line when the scheduler
//! frees their KV blocks for a higher-priority arrival — the token
//! stream resumes later exactly where it left off. `stats` replies carry
//! an "overload" object (policy, preemptions, resumes, swap bytes,
//! admission rejections, goodput).
//!
//! Architecture: the acceptor spawns a reader thread per connection; a
//! dedicated writer thread per connection serialises all reply lines
//! (events for concurrent requests interleave safely). Readers push typed
//! `Inbound` messages into a shared queue; the engine thread — which owns
//! the (non-Send) PJRT state — drains it, steps the scheduler's event
//! loop, and routes each `GenerationEvent` to its connection. If a
//! client disconnects mid-stream, its requests are cancelled so their
//! batch slots free immediately.
//!
//! Failure handling: the scheduler absorbs engine faults itself (retry,
//! polar→dense degradation, bisection blame — see `coordinator::faults`),
//! so a faulting step surfaces here as per-request `engine_fault`
//! terminals and non-terminal `degraded` event lines, never as an engine
//! exit. The engine-death path below is a last resort for faults the
//! scheduler reports as unrecoverable (e.g. the KV pool is lost).

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{
    Completion, FinishReason, GenerationEvent, Mode, Request, SamplingParams, Scheduler,
    SchedulerConfig, SparsityController, StepEngine,
};
use crate::runtime::{Engine, Executor};
use crate::substrate::json::Json;
use crate::substrate::sync::lock_clean;
use crate::tokenizer::Tokenizer;

pub struct ServerConfig {
    pub model_dir: PathBuf,
    pub addr: String,
    pub mode: Mode,
    pub max_batch: usize,
    /// Prompt tokens one scheduler step may spend on prefill chunks
    /// (0 = one chunk bucket; see `SchedulerConfig::prefill_chunk_tokens`).
    pub prefill_chunk_tokens: usize,
}

/// Typed message from a connection thread to the engine thread.
enum Inbound {
    Submit {
        request: Request,
        sink: Sender<Json>,
        stream: bool,
        /// Cleared by the connection on hard disconnect (reader error or
        /// failed write), so the engine can reap in-flight requests whose
        /// client is gone without waiting for a send to fail.
        alive: Arc<AtomicBool>,
    },
    Cancel {
        id: u64,
        /// `None` suppresses the ack line (quiet cancel: used while a
        /// stream is being consumed, where an ack racing the terminal
        /// event would desynchronize the connection's reply stream).
        sink: Option<Sender<Json>>,
    },
    Stats {
        sink: Sender<Json>,
    },
}

struct ReqSink {
    tx: Sender<Json>,
    stream: bool,
    alive: Arc<AtomicBool>,
}

/// Run the server against the real PJRT engine; blocks until a shutdown
/// command arrives. `on_ready` receives the bound address (useful with
/// port 0).
pub fn serve(cfg: ServerConfig, on_ready: impl FnOnce(String)) -> Result<()> {
    let ServerConfig { model_dir, addr, mode, max_batch, prefill_chunk_tokens } = cfg;
    serve_with(&addr, on_ready, move || {
        let exec = Arc::new(Executor::load(&model_dir)?);
        let engine = Engine::new(exec);
        let ctl = SparsityController::for_engine(mode, &engine);
        ctl.validate(engine.exec.manifest())?;
        Ok(Scheduler::new(
            engine,
            ctl,
            SchedulerConfig {
                max_batch,
                compact: true,
                prefill_chunk_tokens,
                ..Default::default()
            },
        ))
    })
}

/// Run the server over any [`StepEngine`]-backed scheduler. The factory
/// runs inside the engine thread, so the engine itself need not be `Send`
/// (PJRT state is not). Used directly by the protocol tests, which serve
/// the mock engine without AOT artifacts.
pub fn serve_with<E, F>(addr: &str, on_ready: impl FnOnce(String), make: F) -> Result<()>
where
    E: StepEngine + 'static,
    F: FnOnce() -> Result<Scheduler<E>> + Send + 'static,
{
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?.to_string();
    let queue: Arc<Mutex<Vec<Inbound>>> = Arc::new(Mutex::new(Vec::new()));
    let shutdown = Arc::new(AtomicBool::new(false));
    let next_id = Arc::new(AtomicU64::new(1));

    // Engine thread owns all engine state and the scheduler.
    let q2 = queue.clone();
    let sd2 = shutdown.clone();
    let poke_addr = local.clone();
    let engine_thread = std::thread::spawn(move || -> Result<()> {
        let mut sched = match make() {
            Ok(s) => s,
            Err(e) => {
                // a server that cannot build its engine must not sit
                // accepting connections it can never answer
                fail_queue(&q2, &format!("engine error: {e:#}"));
                sd2.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(&poke_addr); // wake the acceptor
                return Err(e);
            }
        };
        let tok = Tokenizer::new();
        let mut sinks: HashMap<u64, ReqSink> = HashMap::new();
        loop {
            for inb in lock_clean(&q2).drain(..) {
                match inb {
                    Inbound::Submit { request, sink, stream, alive } => {
                        // prompts past the largest seq bucket are a
                        // structured rejection, not the old silent
                        // truncation — and they never burn a batch slot
                        let limit = sched.max_prompt_len();
                        if request.prompt_ids.len() > limit {
                            // counted here because the request never
                            // reaches the scheduler's own backstop
                            sched.metrics.rejected_prompts += 1;
                            let mut err = error_json(
                                "prompt_too_long",
                                (request.id as usize).into(),
                            );
                            err.set("limit", limit.into());
                            err.set("prompt_len", request.prompt_ids.len().into());
                            let _ = sink.send(err);
                        } else if request
                            .deadline
                            .is_some_and(|d| request.enqueued_at.elapsed() >= d)
                        {
                            // SLO already blown before admission: shed it
                            // here — zero scheduler work, zero KV blocks
                            sched.metrics.admission_rejections += 1;
                            let _ = sink.send(error_json(
                                "deadline_expired",
                                (request.id as usize).into(),
                            ));
                        } else {
                            sinks.insert(request.id, ReqSink { tx: sink, stream, alive });
                            sched.enqueue(request);
                        }
                    }
                    Inbound::Cancel { id, sink } => {
                        let found = sched.cancel(id);
                        if let Some(sink) = sink {
                            let mut ack = Json::obj(vec![
                                ("ok", found.into()),
                                ("id", (id as usize).into()),
                            ]);
                            if !found {
                                ack.set("error", "unknown or finished request id".into());
                            }
                            let _ = sink.send(ack);
                        }
                    }
                    Inbound::Stats { sink } => {
                        let mut stats = sched.metrics.to_json_with_profile(&sched.profile());
                        stats.set("pending", sched.pending_len().into());
                        stats.set("active", sched.active_len().into());
                        stats.set("sparsity", sched.sparsity().stats.to_json());
                        stats.set("prefill", sched.prefill_stats());
                        stats.set("kv", sched.kv_stats());
                        stats.set("overload", sched.overload_stats());
                        stats.set("shards", sched.shard_stats());
                        stats.set("faults", sched.metrics.faults_json());
                        let _ = sink.send(Json::obj(vec![
                            ("ok", true.into()),
                            ("stats", stats),
                        ]));
                    }
                }
            }
            if sched.is_idle() {
                if sd2.load(Ordering::SeqCst) {
                    return Ok(());
                }
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            // route this iteration's events; requests whose client has hung
            // up are cancelled so their slots free immediately
            let events = match sched.step() {
                Ok(events) => events,
                Err(e) => {
                    // last resort only: the scheduler has already retried,
                    // degraded to dense, and run blame isolation before an
                    // error escapes step() — what reaches here is
                    // unrecoverable (e.g. the KV pool itself was lost). A
                    // dead engine must not leave clients blocked on a
                    // reply that will never come: error out every
                    // in-flight request and every undrained inbound
                    // message, then bring the server down
                    let msg = format!("engine error: {e:#}");
                    for (id, sink) in sinks.drain() {
                        let _ = sink.tx.send(error_json(&msg, (id as usize).into()));
                    }
                    fail_queue(&q2, &msg);
                    sd2.store(true, Ordering::SeqCst);
                    let _ = TcpStream::connect(&poke_addr); // wake the acceptor
                    return Err(e);
                }
            };
            let mut dead: Vec<u64> = Vec::new();
            for ev in events {
                route_event(&tok, &mut sinks, ev, &mut dead);
            }
            for id in dead {
                sched.cancel(id);
            }
        }
    });

    on_ready(local);

    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let q = queue.clone();
        let sd = shutdown.clone();
        let ni = next_id.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, q, sd, ni);
        });
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    engine_thread
        .join()
        .map_err(|_| anyhow::anyhow!("engine thread panicked"))??;
    Ok(())
}

/// Send one event to its request's connection; drop + flag the request if
/// the connection is gone. Terminal events release the sink.
fn route_event(
    tok: &Tokenizer,
    sinks: &mut HashMap<u64, ReqSink>,
    ev: GenerationEvent,
    dead: &mut Vec<u64>,
) {
    let rid = ev.request_id();
    let Some(sink) = sinks.get(&rid) else { return };
    let terminal = ev.is_terminal();
    // client hard-disconnected: reap without waiting for a send to fail
    // (non-streaming requests would otherwise hold their slot until the
    // terminal write)
    if !sink.alive.load(Ordering::SeqCst) {
        sinks.remove(&rid);
        if !terminal {
            dead.push(rid);
        }
        return;
    }
    let line = match ev {
        // non-stream requests get only the terminal summary (v1 shape)
        GenerationEvent::Queued { request } if sink.stream => {
            Some(lifecycle_json(request, "queued"))
        }
        GenerationEvent::Prefilled { request } if sink.stream => {
            Some(lifecycle_json(request, "prefilled"))
        }
        // non-terminal: the stream resumes after the scheduler re-admits
        // the request (summary-only clients never see it)
        GenerationEvent::Preempted { request } if sink.stream => {
            Some(lifecycle_json(request, "preempted"))
        }
        // non-terminal: a routed step faulted and this request's stream now
        // runs on the dense fallback entries (tokens are unchanged — the
        // fallback computes the same logits without the sparsity routing)
        GenerationEvent::Degraded { request } if sink.stream => {
            Some(lifecycle_json(request, "degraded"))
        }
        GenerationEvent::Token { request, id, index, text_offset } if sink.stream => {
            Some(Json::obj(vec![
                ("id", (request as usize).into()),
                ("event", "token".into()),
                ("token", (id as i64).into()),
                ("text", tok.decode(&[id]).into()),
                ("index", index.into()),
                ("text_offset", text_offset.into()),
            ]))
        }
        GenerationEvent::Finished(c) | GenerationEvent::Cancelled(c) => {
            Some(summary_json(tok, &c, sink.stream))
        }
        _ => None,
    };
    if let Some(line) = line {
        if sink.tx.send(line).is_err() {
            sinks.remove(&rid);
            if !terminal {
                dead.push(rid);
            }
            return;
        }
    }
    if terminal {
        sinks.remove(&rid);
    }
}

fn lifecycle_json(id: u64, event: &str) -> Json {
    Json::obj(vec![("id", (id as usize).into()), ("event", event.into())])
}

/// Terminal summary line; identical to the v1 reply, plus an `event`
/// field in stream mode.
fn summary_json(tok: &Tokenizer, c: &Completion, stream: bool) -> Json {
    let mut j = Json::obj(vec![
        ("id", (c.id as usize).into()),
        ("text", tok.decode(&c.output_ids).into()),
        (
            "tokens",
            Json::arr(c.output_ids.iter().map(|&t| (t as i64).into())),
        ),
        ("finish", c.finish.as_str().into()),
        ("ttft_ms", (c.ttft_s * 1e3).into()),
        ("e2e_ms", (c.e2e_s * 1e3).into()),
        // prompt tokens served from the shared KV prefix cache (their
        // prefill compute was skipped entirely)
        ("cached_prompt_tokens", c.cached_prompt_tokens.into()),
    ]);
    if stream {
        let event = if c.finish == FinishReason::Cancelled {
            "cancelled"
        } else {
            "finished"
        };
        j.set("event", event.into());
    }
    j
}

fn error_json(msg: &str, id: Json) -> Json {
    Json::obj(vec![("error", msg.into()), ("id", id)])
}

/// Error out every message still sitting in the inbound queue (used when
/// the engine dies so no submitter is left waiting on a dead channel).
fn fail_queue(queue: &Mutex<Vec<Inbound>>, msg: &str) {
    for inb in lock_clean(queue).drain(..) {
        let sink = match inb {
            Inbound::Submit { sink, .. } => Some(sink),
            Inbound::Cancel { sink, .. } => sink,
            Inbound::Stats { sink } => Some(sink),
        };
        if let Some(sink) = sink {
            let _ = sink.send(error_json(msg, Json::Null));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<Mutex<Vec<Inbound>>>,
    shutdown: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
) -> Result<()> {
    let tok = Tokenizer::new();
    let reader = BufReader::new(stream.try_clone()?);
    // one writer thread per connection serialises all reply lines, so
    // events for interleaved requests never corrupt each other
    let (wtx, wrx) = channel::<Json>();
    let wstream = stream.try_clone()?;
    // liveness flag: cleared on reader error (hard disconnect) or failed
    // write, letting the engine reap this connection's requests. A clean
    // EOF (client half-closed after sending, netcat-style) keeps it set
    // so pending replies still go out.
    let alive = Arc::new(AtomicBool::new(true));
    let walive = alive.clone();
    let writer = std::thread::spawn(move || writer_loop(wstream, wrx, walive));
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => {
                alive.store(false, Ordering::SeqCst);
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let j = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ = wtx.send(error_json(&e.to_string(), Json::Null));
                continue;
            }
        };
        match j.get("cmd").as_str() {
            Some("shutdown") => {
                shutdown.store(true, Ordering::SeqCst);
                let _ = wtx.send(Json::obj(vec![("ok", true.into())]));
                drop(wtx);
                let _ = writer.join();
                // poke the acceptor loop awake
                let _ = TcpStream::connect(stream.local_addr()?);
                return Ok(());
            }
            Some("ping") => {
                let _ = wtx.send(Json::obj(vec![("ok", true.into())]));
                continue;
            }
            Some("stats") => {
                lock_clean(&queue).push(Inbound::Stats { sink: wtx.clone() });
                continue;
            }
            Some("cancel") => {
                match j.get("id").as_usize() {
                    Some(id) => {
                        // {"quiet": true} suppresses the ack (PROTOCOL.md)
                        let quiet = j.get("quiet").as_bool().unwrap_or(false);
                        lock_clean(&queue).push(Inbound::Cancel {
                            id: id as u64,
                            sink: if quiet { None } else { Some(wtx.clone()) },
                        });
                    }
                    None => {
                        let _ = wtx.send(error_json(
                            "cancel requires a numeric \"id\"",
                            j.get("id").clone(),
                        ));
                    }
                }
                continue;
            }
            Some(other) => {
                let _ = wtx.send(error_json(&format!("unknown cmd {other:?}"), Json::Null));
                continue;
            }
            None => {}
        }
        // generation request: validated before it can touch a scheduler slot
        let prompt = match j.get("prompt").as_str() {
            Some(p) if !p.trim().is_empty() => p.to_string(),
            Some(_) => {
                let _ = wtx.send(error_json("\"prompt\" must not be empty", Json::Null));
                continue;
            }
            None => {
                let _ = wtx.send(error_json(
                    "request must carry a string \"prompt\" (or a \"cmd\")",
                    Json::Null,
                ));
                continue;
            }
        };
        let params = SamplingParams {
            max_new_tokens: j.get("max_new").as_usize().unwrap_or(32),
            temperature: j.get("temperature").as_f64().unwrap_or(0.0) as f32,
            top_k: j.get("top_k").as_usize().unwrap_or(0),
            seed: j.get("seed").as_usize().unwrap_or(0) as u64,
            ..Default::default()
        };
        let id = next_id.fetch_add(1, Ordering::SeqCst);
        let mut b = Request::builder(tok.encode_prompt(&prompt)).id(id).params(params);
        if let Some(p) = j.get("priority").as_i64() {
            b = b.priority(p as i32);
        }
        if let Some(ms) = j.get("deadline_ms").as_f64() {
            b = b.deadline(Duration::from_secs_f64((ms / 1e3).max(0.0)));
        }
        if let Some(stops) = j.get("stop").as_arr() {
            for s in stops {
                if let Some(s) = s.as_str() {
                    b = b.stop_sequence(tok.encode(s));
                }
            }
        }
        let stream_mode = j.get("stream").as_bool().unwrap_or(false);
        lock_clean(&queue).push(Inbound::Submit {
            request: b.build(),
            sink: wtx.clone(),
            stream: stream_mode,
            alive: alive.clone(),
        });
    }
    drop(wtx);
    let _ = writer.join();
    Ok(())
}

/// Drain reply lines onto the socket until every sender is gone or the
/// client disconnects (a failed write clears the liveness flag and drops
/// the receiver, which makes the engine thread cancel this connection's
/// in-flight requests).
fn writer_loop(mut stream: TcpStream, rx: Receiver<Json>, alive: Arc<AtomicBool>) {
    for line in rx {
        if writeln!(stream, "{line}").is_err() {
            alive.store(false, Ordering::SeqCst);
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Minimal blocking client (examples + integration tests). `request()`
/// keeps the v1 one-line contract; `stream()` exposes the v2 per-token
/// event iterator.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Request ids of streams dropped before their terminal event; their
    /// leftover lines are skipped by `recv()` so the connection stays
    /// usable.
    abandoned: Vec<u64>,
    /// Set after a timed-out or failed read: replies can no longer be
    /// attributed to requests, so further use fails fast instead of
    /// returning another request's reply. Reconnect to recover.
    poisoned: bool,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        // the v1 server replied {"error": "timeout"} after 600s; v2 keeps
        // the same bound client-side so a wedged engine can never leave a
        // blocking call stuck forever
        stream.set_read_timeout(Some(Duration::from_secs(600)))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            abandoned: Vec::new(),
            poisoned: false,
        })
    }

    fn send(&mut self, j: &Json) -> Result<()> {
        if self.poisoned {
            bail!("client desynchronized after a timed-out or failed read; reconnect");
        }
        writeln!(self.writer, "{j}")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Json> {
        if self.poisoned {
            bail!("client desynchronized after a timed-out or failed read; reconnect");
        }
        loop {
            let mut line = String::new();
            match self.reader.read_line(&mut line) {
                Ok(0) => bail!("connection closed by server"),
                Ok(_) => {}
                Err(e) => {
                    // a timed-out read leaves the next reply unattributable
                    // (it may be the late reply of the request that timed
                    // out); poison rather than desynchronize
                    self.poisoned = true;
                    return Err(e.into());
                }
            }
            let j = Json::parse(&line).map_err(anyhow::Error::from)?;
            if let Some(id) = j.get("id").as_usize().map(|x| x as u64) {
                if self.abandoned.contains(&id) {
                    // leftover line from a dropped stream; swallow it and
                    // forget the id once its terminal goes by
                    let terminal = matches!(
                        j.get("event").as_str(),
                        Some("finished" | "cancelled")
                    ) || !j.get("error").is_null();
                    if terminal {
                        self.abandoned.retain(|&x| x != id);
                    }
                    continue;
                }
            }
            return Ok(j);
        }
    }

    /// Blocking generation: one request, one summary line.
    pub fn request(&mut self, prompt: &str, max_new: usize) -> Result<Json> {
        self.send(&Json::obj(vec![
            ("prompt", prompt.into()),
            ("max_new", max_new.into()),
        ]))?;
        self.recv()
    }

    /// Streaming generation: returns an iterator over this request's
    /// event lines (`queued`, `prefilled`, `token`+, then a terminal
    /// `finished`/`cancelled` summary). Extra fields are passed through in
    /// `extra` (e.g. `stop`, `priority`, `deadline_ms`).
    ///
    /// Dropping the iterator mid-stream cancels the request and keeps the
    /// connection usable (remaining lines are swallowed); dropping it
    /// before the *first* event arrives leaves the connection
    /// desynchronized, since the request's id is not yet known — consume
    /// at least one event, or discard the `Client`.
    pub fn stream(&mut self, prompt: &str, max_new: usize) -> Result<TokenStream<'_>> {
        self.stream_with(prompt, max_new, vec![])
    }

    pub fn stream_with(
        &mut self,
        prompt: &str,
        max_new: usize,
        extra: Vec<(&str, Json)>,
    ) -> Result<TokenStream<'_>> {
        let mut req = Json::obj(vec![
            ("prompt", prompt.into()),
            ("max_new", max_new.into()),
            ("stream", true.into()),
        ]);
        for (k, v) in extra {
            req.set(k, v);
        }
        self.send(&req)?;
        Ok(TokenStream { client: self, id: None, done: false })
    }

    /// Cancel a request by server-assigned id and wait for the ack. Use
    /// [`TokenStream::cancel`] instead while a stream is being consumed.
    pub fn cancel(&mut self, id: u64) -> Result<Json> {
        self.send(&Json::obj(vec![
            ("cmd", "cancel".into()),
            ("id", (id as usize).into()),
        ]))?;
        self.recv()
    }

    /// Fetch engine metrics ({"ok": true, "stats": {...}}).
    pub fn stats(&mut self) -> Result<Json> {
        self.send(&Json::obj(vec![("cmd", "stats".into())]))?;
        self.recv()
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.send(&Json::obj(vec![("cmd", "ping".into())]))?;
        self.recv()
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.send(&Json::obj(vec![("cmd", "shutdown".into())]))?;
        let mut line = String::new();
        let _ = self.reader.read_line(&mut line);
        Ok(())
    }
}

/// Iterator over one streamed request's event lines. Lines for other
/// requests on the same connection and command acks are skipped; the
/// iterator ends after the terminal `finished`/`cancelled` (or an error
/// line, which is yielded).
pub struct TokenStream<'a> {
    client: &'a mut Client,
    id: Option<u64>,
    done: bool,
}

/// `{"cmd": "cancel", "id": .., "quiet": true}` — no ack line, so it can
/// never desynchronize a connection whose reply stream is being consumed.
fn quiet_cancel_json(id: u64) -> Json {
    Json::obj(vec![
        ("cmd", "cancel".into()),
        ("id", (id as usize).into()),
        ("quiet", true.into()),
    ])
}

impl TokenStream<'_> {
    /// Server-assigned request id, known once the first event arrives.
    pub fn id(&self) -> Option<u64> {
        self.id
    }

    /// Cancel this stream's request. Sent as a quiet cancel (no ack
    /// line), so the connection's reply stream stays in sync even when
    /// the cancel races a natural finish; the outcome is observed via
    /// the terminal event (`cancelled`, or `finished` if the race was
    /// lost).
    pub fn cancel(&mut self) -> Result<()> {
        let id = self
            .id
            .context("stream id not known yet (consume at least one event first)")?;
        writeln!(self.client.writer, "{}", quiet_cancel_json(id))?;
        Ok(())
    }
}

impl Drop for TokenStream<'_> {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        if let Some(id) = self.id {
            // keep the connection usable after an abandoned stream: cancel
            // quietly and have recv() swallow the remaining lines up to
            // this request's terminal
            let _ = writeln!(self.client.writer, "{}", quiet_cancel_json(id));
            self.client.abandoned.push(id);
        }
        // id unknown (no event consumed yet): lines cannot be attributed —
        // see the `stream()` docs.
    }
}

impl Iterator for TokenStream<'_> {
    type Item = Result<Json>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        loop {
            let j = match self.client.recv() {
                Ok(j) => j,
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            };
            if !j.get("error").is_null() {
                self.done = true;
                return Some(Ok(j));
            }
            if j.get("event").as_str().is_none() {
                continue; // command ack for this connection; not an event
            }
            let id = j.get("id").as_usize().map(|x| x as u64);
            match (self.id, id) {
                (None, Some(i)) => self.id = Some(i),
                (Some(mine), Some(i)) if i != mine => continue, // other request
                _ => {}
            }
            if matches!(j.get("event").as_str(), Some("finished" | "cancelled")) {
                self.done = true;
            }
            return Some(Ok(j));
        }
    }
}
