//! Mini property-testing harness (no `proptest` in the vendored set).
//!
//! `check(name, cases, |g| { ... })` runs the closure against `cases`
//! generated inputs drawn through the `Gen` handle. On failure it reruns
//! with the failing seed to confirm, then panics with the seed so the case
//! is reproducible (`PROP_SEED=<n>` reruns a single seed).

use super::rng::Rng;

pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f64() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// Random subset of size k from 0..n without replacement.
    pub fn distinct(&mut self, k: usize, n: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut pool: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut pool);
        pool.truncate(k);
        pool
    }

    pub fn ascii_string(&mut self, max_len: usize) -> String {
        let len = self.usize_in(0, max_len + 1);
        (0..len)
            .map(|_| (self.usize_in(0x20, 0x7f) as u8) as char)
            .collect()
    }

    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let len = self.usize_in(0, max_len + 1);
        (0..len).map(|_| self.usize_in(0, 256) as u8).collect()
    }
}

/// Run `f` over `cases` generated inputs; panics with the failing seed.
pub fn check<F: Fn(&mut Gen) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    // env override: rerun a single seed
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be u64");
        let mut g = Gen::new(seed);
        if let Err(msg) = f(&mut g) {
            panic!("[{name}] failed at PROP_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = 0x9E3779B9u64.wrapping_mul(case + 1) ^ hash_name(name);
        let mut g = Gen::new(seed);
        if let Err(msg) = f(&mut g) {
            panic!("[{name}] case {case} failed (PROP_SEED={seed}): {msg}");
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_is_distinct() {
        check("distinct", 50, |g| {
            let n = g.usize_in(1, 40);
            let k = g.usize_in(0, n + 1);
            let v = g.distinct(k, n);
            let mut s = v.clone();
            s.sort();
            s.dedup();
            prop_assert!(s.len() == v.len(), "duplicates in {v:?}");
            prop_assert!(v.iter().all(|&x| x < n), "out of range in {v:?}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".to_string()));
    }
}
