//! Poison-tolerant synchronization helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicking thread into a cascade:
//! every later locker panics on the poisoned mutex, so a single bad step
//! takes down stats/profile reporting and ultimately the server. For the
//! data we guard (profiling counters, request queues) the invariant is
//! "the value is a plain struct, always valid" — there is no partially
//! applied multi-field transaction to fear — so recovering the inner
//! value is strictly better than propagating the poison.

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, recovering the inner value if a previous holder
/// panicked. Use on hot paths where availability beats poison
/// propagation (profiles, queues, fault stashes).
pub fn lock_clean<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_clean_survives_poison() {
        let m = Arc::new(Mutex::new(41u64));
        let m2 = m.clone();
        // poison it
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        // lock_clean still hands out the value, and writes stick
        *lock_clean(&m) += 1;
        assert_eq!(*lock_clean(&m), 42);
    }

    #[test]
    fn lock_clean_plain_path() {
        let m = Mutex::new(vec![1, 2, 3]);
        lock_clean(&m).push(4);
        assert_eq!(lock_clean(&m).len(), 4);
    }
}
