//! Deterministic PRNG (no `rand` in the vendored set).
//!
//! SplitMix64 seeding into xoshiro256++ (Blackman & Vigna), plus the
//! distribution draws the workload generator and sampler need:
//! uniforms, exponential inter-arrival gaps (Poisson process), categorical
//! sampling from logits, and Fisher-Yates shuffles.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiasedness.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Exponential draw with the given rate (mean 1/rate) — Poisson gaps.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalised logits with temperature.
    /// temperature == 0 -> argmax.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> usize {
        if temperature <= 0.0 {
            return argmax(logits);
        }
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f64> =
            logits.iter().map(|&l| (((l - max) / temperature) as f64).exp()).collect();
        let sum: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= sum;
        }
        let mut u = self.f64();
        for (i, p) in probs.iter().enumerate() {
            if u < *p {
                return i;
            }
            u -= p;
        }
        probs.len() - 1
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(3, 17);
            assert!((3..17).contains(&n));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn sample_logits_argmax_at_zero_temp() {
        let mut r = Rng::new(9);
        assert_eq!(r.sample_logits(&[0.1, 5.0, -2.0], 0.0), 1);
    }

    #[test]
    fn sample_logits_respects_distribution() {
        let mut r = Rng::new(11);
        let logits = [0.0f32, (4.0f32).ln()]; // p = [0.2, 0.8]
        let n = 50_000;
        let ones: usize = (0..n).filter(|_| r.sample_logits(&logits, 1.0) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.8).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
