//! Tiny declarative CLI flag parser (no `clap` in the vendored set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults and required flags, and renders a usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
    required: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
    positionals: Vec<(String, String)>, // (name, help)
    values: BTreeMap<String, String>,
    pos_values: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
            required: false,
        });
        self
    }

    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
            required: true,
        });
        self
    }

    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
            required: false,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nusage: {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [flags]\n\nflags:\n");
        for f in &self.flags {
            let d = match (&f.default, f.is_bool) {
                (_, true) => String::new(),
                (Some(d), _) if !d.is_empty() => format!(" (default: {d})"),
                (Some(_), _) => String::new(),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse; returns Err(usage-or-error string) on bad input or --help.
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?
                    .clone();
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{name} needs a value"))?
                };
                self.values.insert(name, value);
            } else {
                self.pos_values.push(a.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if !self.values.contains_key(&f.name) {
                match &f.default {
                    Some(d) => {
                        self.values.insert(f.name.clone(), d.clone());
                    }
                    None if f.required => {
                        return Err(format!("missing required --{}\n\n{}", f.name, self.usage()));
                    }
                    None => {}
                }
            }
        }
        if self.pos_values.len() > self.positionals.len() {
            return Err(format!(
                "unexpected positional {:?}\n\n{}",
                self.pos_values[self.positionals.len()],
                self.usage()
            ));
        }
        Ok(Parsed {
            values: self.values,
            pos_values: self.pos_values,
        })
    }
}

#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pos_values: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} must be an integer, got {:?}", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} must be a number, got {:?}", self.get(name)))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.pos_values.get(i).map(|s| s.as_str())
    }

    /// Comma-separated list helper ("a,b,c" -> vec).
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let v = self.get(name);
        if v.is_empty() {
            vec![]
        } else {
            v.split(',').map(|s| s.trim().to_string()).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new("t", "test")
            .flag("model", "opt-tiny", "model name")
            .flag("batch", "8", "batch size")
            .switch("verbose", "chatty")
            .required("out", "output path")
            .positional("cmd", "subcommand")
    }

    #[test]
    fn parses_flags_and_positionals() {
        let p = spec()
            .parse(&argv(&["run", "--model=opt-small", "--batch", "16", "--out", "/tmp/x", "--verbose"]))
            .unwrap();
        assert_eq!(p.positional(0), Some("run"));
        assert_eq!(p.get("model"), "opt-small");
        assert_eq!(p.get_usize("batch").unwrap(), 16);
        assert!(p.get_bool("verbose"));
        assert_eq!(p.get("out"), "/tmp/x");
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&argv(&["--out", "x"])).unwrap();
        assert_eq!(p.get("model"), "opt-tiny");
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&argv(&["run"])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(spec().parse(&argv(&["--nope", "1", "--out", "x"])).is_err());
    }

    #[test]
    fn list_helper() {
        let p = Args::new("t", "")
            .flag("models", "a,b", "")
            .parse(&argv(&[]))
            .unwrap();
        assert_eq!(p.get_list("models"), vec!["a", "b"]);
    }
}
