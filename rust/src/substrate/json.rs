//! Minimal JSON codec (parser + serializer).
//!
//! The offline image vendors no `serde`, so the manifest loader, the TCP
//! wire protocol, the eval-task reader and the results writers all run on
//! this in-tree implementation. It supports the full JSON grammar (RFC
//! 8259): objects, arrays, strings with escapes incl. \uXXXX surrogate
//! pairs, numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array element lookup; Null when out of range.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Insert/overwrite an object field; no-op on non-objects.
    pub fn set(&mut self, key: &str, v: Json) -> &mut Json {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), v);
        }
        self
    }

    // -- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000u32
                                        + ((hi as u32 - 0xD800) << 10)
                                        + (lo as u32 - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi as u32
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                _ => {
                    // copy one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xC0 == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no NaN/inf
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => fmt_num(*n, out),
        Json::Str(s) => esc(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_i64(), Some(1));
        assert_eq!(v.get("a").at(1).get("b").as_str(), Some("x"));
        assert!(v.get("a").at(2).is_null());
        assert_eq!(v.get("c").as_bool(), Some(false));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // surrogate pair for U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "\"abc", "01x", "{\"a\" 1}", "[1] x", "nul"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d\ne"},"e":-2.5,"f":true,"g":null}"#,
            r#"[[],{},"",0]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "roundtrip {c}");
        }
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn set_inserts_and_overwrites() {
        let mut j = Json::obj(vec![("a", 1usize.into())]);
        j.set("b", "x".into());
        j.set("a", 2usize.into());
        assert_eq!(j.get("a").as_i64(), Some(2));
        assert_eq!(j.get("b").as_str(), Some("x"));
        // no-op on non-objects
        let mut n = Json::Num(1.0);
        n.set("a", Json::Null);
        assert_eq!(n, Json::Num(1.0));
    }
}
