//! Latency/throughput statistics: reservoir-free exact percentile samples,
//! streaming mean/std, and a fixed-window throughput meter.

use std::time::Duration;

/// Collects raw samples (seconds) and reports mean / std / percentiles.
/// Exact (keeps all samples) — bench runs are small enough.
#[derive(Debug, Default, Clone)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn push_duration(&mut self, d: Duration) {
        self.push(d.as_secs_f64());
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut v = self.xs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (q / 100.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p90(&self) -> f64 {
        self.percentile(90.0)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn summary_ms(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p90={:.3}ms p99={:.3}ms",
            self.len(),
            self.mean() * 1e3,
            self.p50() * 1e3,
            self.p90() * 1e3,
            self.p99() * 1e3
        )
    }
}

/// Tokens/requests per second over a measured wall-clock span.
#[derive(Debug, Default, Clone, Copy)]
pub struct Throughput {
    pub units: u64,
    pub elapsed_s: f64,
}

impl Throughput {
    pub fn add(&mut self, units: u64, elapsed: Duration) {
        self.units += units;
        self.elapsed_s += elapsed.as_secs_f64();
    }

    pub fn per_second(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            return 0.0;
        }
        self.units as f64 / self.elapsed_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!(s.p99() > 98.0 && s.p99() <= 100.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn throughput() {
        let mut t = Throughput::default();
        t.add(100, Duration::from_secs_f64(0.5));
        t.add(100, Duration::from_secs_f64(0.5));
        assert!((t.per_second() - 200.0).abs() < 1e-9);
    }
}
