//! In-tree substrates (the offline image vendors only the `xla` crate's
//! closure, so JSON, CLI parsing, PRNG, stats and property testing are all
//! implemented here).

pub mod argparse;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
