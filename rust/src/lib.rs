//! # Polar Sparsity — batched LLM serving with scalable contextual sparsity
//!
//! Reproduction of *Polar Sparsity: High Throughput Batched LLM Inferencing
//! with Scalable Contextual Sparsity* (NeurIPS 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — serving coordinator: continuous batcher,
//!   prefill/decode scheduler, KV-slot manager, sparsity controller,
//!   sampler, metrics, TCP server, workload generator, bench harness.
//! * **L2/L1 (python/, build-time only)** — JAX transformer + Pallas
//!   selective-head-attention and fused sparse-GEMM kernels, AOT-lowered
//!   to HLO text that this crate compiles and runs via PJRT.
//!
//! Python never runs on the request path: `artifacts/` is built once by
//! `make artifacts`, after which the binary is self-contained.

pub mod bench;
pub mod coordinator;
pub mod runtime;
pub mod server;
pub mod substrate;
pub mod tokenizer;
pub mod workload;
