//! Byte-level tokenizer, mirroring python/compile/corpus.py exactly:
//! ids 0..=255 are raw bytes; 256 = PAD, 257 = BOS, 258 = EOS.

pub const PAD: i32 = 256;
pub const BOS: i32 = 257;
pub const EOS: i32 = 258;
pub const VOCAB: usize = 259;

/// Bytes a token contributes to decoded text (specials contribute none).
/// Used by the scheduler to compute `Token { text_offset }` incrementally.
pub fn token_byte_len(id: i32) -> usize {
    if (0..256).contains(&id) {
        1
    } else {
        0
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Self {
        Tokenizer
    }

    /// UTF-8 bytes -> ids (no specials added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    /// Prompt encoding as the models were trained: the corpus stream is
    /// BOS followed by newline-separated lines, so a fresh prompt is
    /// [BOS, '\n', ...] — the newline puts the model at a line start.
    pub fn encode_prompt(&self, text: &str) -> Vec<i32> {
        let mut v = Vec::with_capacity(text.len() + 2);
        v.push(BOS);
        v.push(b'\n' as i32);
        v.extend(self.encode(text));
        v
    }

    /// ids -> text; specials and invalid UTF-8 are dropped/replaced.
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&i| (0..256).contains(&i))
            .map(|&i| i as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, id: i32) -> bool {
        !(0..256).contains(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::substrate::prop::check;

    #[test]
    fn ascii_roundtrip() {
        let t = Tokenizer::new();
        let s = "copy:abc=abc\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn prompt_has_bos_and_line_start() {
        let t = Tokenizer::new();
        let ids = t.encode_prompt("hi");
        assert_eq!(ids[0], BOS);
        assert_eq!(ids[1], b'\n' as i32);
        assert_eq!(&ids[2..], &[104, 105]);
    }

    #[test]
    fn specials_dropped_in_decode() {
        let t = Tokenizer::new();
        assert_eq!(t.decode(&[BOS, 104, 105, EOS, PAD]), "hi");
    }

    #[test]
    fn prop_bytes_roundtrip() {
        check("tokenizer-roundtrip", 100, |g| {
            let bytes = g.bytes(64);
            let t = Tokenizer::new();
            let ids: Vec<i32> = bytes.iter().map(|&b| b as i32).collect();
            let text = t.decode(&ids);
            // valid UTF-8 inputs round-trip exactly
            if let Ok(s) = std::str::from_utf8(&bytes) {
                prop_assert!(text == s, "mismatch for {bytes:?}");
                prop_assert!(t.encode(s) == ids, "encode mismatch");
            }
            Ok(())
        });
    }
}
