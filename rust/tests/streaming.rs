//! Protocol-v2 integration tests: the TCP server over the mock engine
//! (no AOT artifacts needed). Covers streaming event ordering, interleaved
//! multi-request connections, mid-generation cancellation, the stats
//! command, structured rejection of malformed input, and fault handling
//! over the wire (`degraded` event lines, `engine_fault` terminals, and
//! ledger cleanup when a preempted request is cancelled).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use polar_sparsity::coordinator::mock::MockEngine;
use polar_sparsity::coordinator::{
    FaultInjector, FaultScript, Mode, Scheduler, SchedulerConfig, SparsityController,
};
use polar_sparsity::server::{serve_with, Client};
use polar_sparsity::substrate::json::Json;

/// Serve the mock engine on an ephemeral port; returns (addr, join handle).
fn spawn_server(step_delay: Duration) -> (String, JoinHandle<anyhow::Result<()>>) {
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        serve_with(
            "127.0.0.1:0",
            move |addr| {
                let _ = tx.send(addr);
            },
            move || {
                Ok(Scheduler::new(
                    MockEngine::new().with_step_delay(step_delay),
                    SparsityController::new(Mode::Dense),
                    SchedulerConfig { max_batch: 8, compact: true, ..Default::default() },
                ))
            },
        )
    });
    (rx.recv().expect("server address"), h)
}

fn shut_down(addr: &str, h: JoinHandle<anyhow::Result<()>>) {
    Client::connect(addr).unwrap().shutdown().unwrap();
    h.join().expect("server thread").expect("server result");
}

#[test]
fn streaming_events_are_ordered_and_ttft_is_measured() {
    let (addr, h) = spawn_server(Duration::ZERO);
    let mut c = Client::connect(&addr).unwrap();
    // mock LM: prompt ending 'A' (65) generates 66, 67, ... ("BCDEF")
    let events: Vec<Json> = c
        .stream("A", 5)
        .unwrap()
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let kinds: Vec<&str> = events
        .iter()
        .map(|e| e.get("event").as_str().unwrap())
        .collect();
    assert_eq!(
        kinds,
        vec!["queued", "prefilled", "token", "token", "token", "token", "token", "finished"],
        "events: {events:?}"
    );
    // all events tagged with the same server-assigned id
    let id = events[0].get("id").as_usize().unwrap();
    assert!(events.iter().all(|e| e.get("id").as_usize() == Some(id)));
    // token payloads: id, decoded text, index, text_offset
    for (k, ev) in events[2..7].iter().enumerate() {
        assert_eq!(ev.get("token").as_i64(), Some(66 + k as i64));
        assert_eq!(ev.get("index").as_usize(), Some(k));
        assert_eq!(ev.get("text_offset").as_usize(), Some(k));
    }
    // at least one token strictly precedes the terminal line, and the
    // summary's TTFT comes from the first-token event timestamp
    let fin = events.last().unwrap();
    assert_eq!(fin.get("text").as_str(), Some("BCDEF"));
    assert_eq!(fin.get("finish").as_str(), Some("length"));
    let ttft = fin.get("ttft_ms").as_f64().unwrap();
    let e2e = fin.get("e2e_ms").as_f64().unwrap();
    assert!(ttft >= 0.0 && ttft <= e2e, "ttft {ttft} e2e {e2e}");
    shut_down(&addr, h);
}

#[test]
fn interleaved_requests_share_one_connection() {
    let (addr, h) = spawn_server(Duration::ZERO);
    // raw socket: two streaming requests pipelined back-to-back
    let sock = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut w = sock.try_clone().unwrap();
    writeln!(w, r#"{{"prompt": "A", "max_new": 4, "stream": true}}"#).unwrap();
    writeln!(w, r#"{{"prompt": "K", "max_new": 4, "stream": true}}"#).unwrap();
    let mut by_id: std::collections::BTreeMap<usize, Vec<Json>> = Default::default();
    let mut finished = 0;
    while finished < 2 {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed early");
        let j = Json::parse(&line).unwrap();
        assert!(j.get("error").is_null(), "unexpected error line: {j}");
        let id = j.get("id").as_usize().unwrap();
        if j.get("event").as_str() == Some("finished") {
            finished += 1;
        }
        by_id.entry(id).or_default().push(j);
    }
    assert_eq!(by_id.len(), 2, "expected two interleaved requests");
    // each request's own event stream is well-ordered and complete
    let mut texts: Vec<String> = Vec::new();
    for (_, evs) in by_id {
        let kinds: Vec<&str> = evs.iter().map(|e| e.get("event").as_str().unwrap()).collect();
        assert_eq!(
            kinds,
            vec!["queued", "prefilled", "token", "token", "token", "token", "finished"]
        );
        texts.push(evs.last().unwrap().get("text").as_str().unwrap().to_string());
    }
    texts.sort();
    // 'A' (65) -> BCDE; 'K' (75) -> LMNO
    assert_eq!(texts, vec!["BCDE".to_string(), "LMNO".to_string()]);
    shut_down(&addr, h);
}

#[test]
fn cancel_stops_token_flow_and_frees_the_slot() {
    // slow the mock down so the cancel lands mid-generation
    let (addr, h) = spawn_server(Duration::from_millis(5));
    let mut c = Client::connect(&addr).unwrap();
    // start at 'A' with a huge budget: would run ~60 steps to cache limit
    let mut stream = c.stream("A", 1000).unwrap();
    let mut tokens = 0;
    let mut terminal: Option<Json> = None;
    while let Some(ev) = stream.next() {
        let ev = ev.unwrap();
        match ev.get("event").as_str() {
            Some("token") => {
                tokens += 1;
                if tokens == 3 {
                    stream.cancel().unwrap();
                }
            }
            Some("cancelled") => terminal = Some(ev),
            Some("finished") => panic!("request finished despite cancel"),
            _ => {}
        }
    }
    let term = terminal.expect("terminal cancelled event");
    assert_eq!(term.get("finish").as_str(), Some("cancelled"));
    let emitted = term.get("tokens").as_arr().unwrap().len();
    assert!(
        (3..20).contains(&emitted),
        "token flow should stop promptly after cancel (saw {emitted})"
    );
    // the scheduler released the slot: server-side metrics agree
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("ok").as_bool(), Some(true));
    let s = stats.get("stats");
    assert_eq!(s.get("active").as_usize(), Some(0));
    assert_eq!(s.get("pending").as_usize(), Some(0));
    assert_eq!(s.get("cancelled_requests").as_usize(), Some(1));
    shut_down(&addr, h);
}

#[test]
fn stats_command_reports_engine_metrics() {
    let (addr, h) = spawn_server(Duration::ZERO);
    let mut c = Client::connect(&addr).unwrap();
    let resp = c.request("A", 4).unwrap();
    assert_eq!(resp.get("finish").as_str(), Some("length"));
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("ok").as_bool(), Some(true));
    let s = stats.get("stats");
    assert_eq!(s.get("completed_requests").as_usize(), Some(1));
    assert!(s.get("decode_steps").as_usize().unwrap() > 0);
    assert!(!s.get("ttft_ms_p50").is_null());
    shut_down(&addr, h);
}

#[test]
fn malformed_and_promptless_requests_are_rejected() {
    let (addr, h) = spawn_server(Duration::ZERO);
    let sock = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut w = sock.try_clone().unwrap();
    let read_json = |reader: &mut BufReader<TcpStream>| {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0);
        Json::parse(&line).unwrap()
    };
    // broken JSON -> structured error, connection stays usable
    writeln!(w, "this is not json").unwrap();
    let e = read_json(&mut reader);
    assert!(!e.get("error").is_null());
    assert!(e.get("id").is_null());
    // promptless object -> rejected before reaching the scheduler
    writeln!(w, "{{}}").unwrap();
    let e = read_json(&mut reader);
    assert!(e.get("error").as_str().unwrap().contains("prompt"));
    // empty prompt -> rejected too
    writeln!(w, r#"{{"prompt": "   "}}"#).unwrap();
    let e = read_json(&mut reader);
    assert!(!e.get("error").is_null());
    // unknown command -> structured error
    writeln!(w, r#"{{"cmd": "nope"}}"#).unwrap();
    let e = read_json(&mut reader);
    assert!(e.get("error").as_str().unwrap().contains("unknown cmd"));
    // the connection still serves valid requests afterwards
    writeln!(w, r#"{{"prompt": "A", "max_new": 2}}"#).unwrap();
    let ok = read_json(&mut reader);
    assert_eq!(ok.get("text").as_str(), Some("BC"));
    // none of the rejects burned a scheduler slot
    let mut c = Client::connect(&addr).unwrap();
    let s = c.stats().unwrap();
    assert_eq!(s.get("stats").get("completed_requests").as_usize(), Some(1));
    shut_down(&addr, h);
}

#[test]
fn dropped_stream_cancels_and_connection_stays_usable() {
    let (addr, h) = spawn_server(Duration::from_millis(5));
    let mut c = Client::connect(&addr).unwrap();
    {
        let mut stream = c.stream("A", 1000).unwrap();
        // consume a couple of events so the id is known, then drop the
        // iterator mid-stream
        stream.next().unwrap().unwrap();
        stream.next().unwrap().unwrap();
    }
    // the abandoned request was cancelled and its leftover lines are
    // swallowed: the same connection keeps answering correctly
    let resp = c.request("K", 2).unwrap();
    assert_eq!(resp.get("text").as_str(), Some("LM"));
    let s = c.stats().unwrap();
    assert_eq!(s.get("stats").get("cancelled_requests").as_usize(), Some(1));
    assert_eq!(s.get("stats").get("active").as_usize(), Some(0));
    shut_down(&addr, h);
}

#[test]
fn overlong_prompt_rejected_exact_fill_accepted() {
    let (addr, h) = spawn_server(Duration::ZERO);
    let mut c = Client::connect(&addr).unwrap();
    // mock's largest seq bucket is 64; encode_prompt adds [BOS, '\n'],
    // so 63 chars -> 65 prompt ids -> structured rejection with the limit
    let too_long = "A".repeat(63);
    let resp = c.request(&too_long, 4).unwrap();
    assert_eq!(resp.get("error").as_str(), Some("prompt_too_long"));
    assert_eq!(resp.get("limit").as_usize(), Some(64));
    assert_eq!(resp.get("prompt_len").as_usize(), Some(65));
    // 62 chars -> exactly 64 ids: accepted, first token emitted out of
    // the final prefill chunk, then the cache is full
    let exact = "A".repeat(62);
    let resp = c.request(&exact, 4).unwrap();
    assert!(resp.get("error").is_null(), "exact fill rejected: {resp}");
    assert_eq!(resp.get("finish").as_str(), Some("cache_limit"));
    assert_eq!(resp.get("text").as_str(), Some("B"));
    // the rejection never burned a slot, but it IS counted
    let s = c.stats().unwrap();
    assert_eq!(s.get("stats").get("completed_requests").as_usize(), Some(1));
    assert_eq!(s.get("stats").get("rejected_prompts").as_usize(), Some(1));
    shut_down(&addr, h);
}

#[test]
fn stats_expose_prefill_object() {
    let (addr, h) = spawn_server(Duration::ZERO);
    let mut c = Client::connect(&addr).unwrap();
    // a 40-char prompt (42 ids) spans 3 chunks of the mock's 16
    let resp = c.request(&"A".repeat(40), 2).unwrap();
    assert!(resp.get("error").is_null(), "{resp}");
    let s = c.stats().unwrap();
    let p = s.get("stats").get("prefill");
    assert!(p.get("chunks").as_usize().unwrap() >= 3, "{p}");
    assert!(p.get("tokens").as_usize().unwrap() >= 42);
    assert_eq!(p.get("queued_prompt_tokens").as_usize(), Some(0));
    let b = p.get("ttft_breakdown");
    assert!(b.get("queued_to_first_chunk_ms_p50").as_f64().is_some());
    assert!(b.get("first_to_last_chunk_ms_p50").as_f64().is_some());
    assert!(b.get("last_chunk_to_first_token_ms_p50").as_f64().is_some());
    shut_down(&addr, h);
}

#[test]
fn cancel_mid_decode_releases_kv_blocks_to_baseline() {
    // satellite: cancel (and client disconnect) must release a request's
    // KV blocks immediately. Observe the pool through stats.kv: after a
    // cancel lands mid-decode, blocks_in_use returns to 0 and the free
    // count to its baseline (mock pool: 33 blocks, 32 grantable).
    let (addr, h) = spawn_server(Duration::from_millis(5));
    let mut c = Client::connect(&addr).unwrap();
    let baseline = {
        let s = c.stats().unwrap();
        let kv = s.get("stats").get("kv");
        assert_eq!(kv.get("pool_blocks").as_usize(), Some(33));
        assert_eq!(kv.get("blocks_in_use").as_usize(), Some(0));
        // grantable = free list + evictable cached (disjoint gauges)
        kv.get("blocks_available").as_usize().unwrap()
    };
    let mut stream = c.stream("A", 1000).unwrap();
    let mut tokens = 0;
    while let Some(ev) = stream.next() {
        let ev = ev.unwrap();
        match ev.get("event").as_str() {
            Some("token") => {
                tokens += 1;
                if tokens == 3 {
                    stream.cancel().unwrap();
                }
            }
            Some("cancelled") => break,
            Some("finished") => panic!("request finished despite cancel"),
            _ => {}
        }
    }
    let s = c.stats().unwrap();
    let kv = s.get("stats").get("kv");
    assert_eq!(kv.get("blocks_in_use").as_usize(), Some(0), "blocks not released: {kv}");
    assert_eq!(kv.get("blocks_available").as_usize(), Some(baseline));
    // the disjoint gauges partition the pool (minus the null block)
    let sum = kv.get("blocks_in_use").as_usize().unwrap()
        + kv.get("blocks_cached").as_usize().unwrap()
        + kv.get("blocks_free").as_usize().unwrap();
    assert_eq!(sum, 32, "gauges must partition the pool: {kv}");
    assert!(kv.get("block_allocs").as_usize().unwrap() >= 1);
    shut_down(&addr, h);
}

#[test]
fn cancel_unknown_id_acks_with_error() {
    let (addr, h) = spawn_server(Duration::ZERO);
    let mut c = Client::connect(&addr).unwrap();
    let ack = c.cancel(424242).unwrap();
    assert_eq!(ack.get("ok").as_bool(), Some(false));
    assert!(!ack.get("error").is_null());
    shut_down(&addr, h);
}

#[test]
fn stop_sequences_and_deadline_ride_the_wire() {
    let (addr, h) = spawn_server(Duration::ZERO);
    let mut c = Client::connect(&addr).unwrap();
    // 'A' generates "BCDEF..."; stop once the output ends with "CD"
    let events: Vec<Json> = c
        .stream_with("A", 50, vec![("stop", Json::arr(vec![Json::str("CD")]))])
        .unwrap()
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let fin = events.last().unwrap();
    assert_eq!(fin.get("finish").as_str(), Some("stop_sequence"));
    assert_eq!(fin.get("text").as_str(), Some("BCD"));
    // an already-expired deadline never reaches the scheduler: it is
    // shed at admission with a structured error line
    let events: Vec<Json> = c
        .stream_with("A", 50, vec![("deadline_ms", 0.0.into())])
        .unwrap()
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    assert_eq!(events.len(), 1, "expected only the rejection line: {events:?}");
    assert_eq!(events[0].get("error").as_str(), Some("deadline_expired"));
    assert!(!events[0].get("id").is_null());
    // negative deadlines clamp to zero and take the same path
    let events: Vec<Json> = c
        .stream_with("A", 50, vec![("deadline_ms", (-5.0).into())])
        .unwrap()
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    assert_eq!(events[0].get("error").as_str(), Some("deadline_expired"));
    let s = c.stats().unwrap();
    let ov = s.get("stats").get("overload");
    assert_eq!(ov.get("admission_rejections").as_usize(), Some(2));
    shut_down(&addr, h);
}

/// Tentpole, observed end-to-end over the wire: under KV block
/// pressure a higher-priority arrival preempts a streaming request,
/// which sees a non-terminal "preempted" event and then resumes with
/// its token stream intact (indices contiguous, no re-emission).
#[test]
fn preemption_rides_the_wire_and_stream_resumes() {
    // small pool (8 blocks = 7 usable) so block pressure is reachable:
    // victim (33 ids + 24 new -> 4 predicted blocks) holds 3 + 1
    // reserved; the hot request (49 ids + 8 new -> 4 blocks) cannot fit
    // without preempting.
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        serve_with(
            "127.0.0.1:0",
            move |addr| {
                let _ = tx.send(addr);
            },
            move || {
                Ok(Scheduler::new(
                    MockEngine::new()
                        .with_pool_blocks(8)
                        .with_step_delay(Duration::from_millis(2)),
                    SparsityController::new(Mode::Dense),
                    SchedulerConfig { max_batch: 8, ..Default::default() },
                ))
            },
        )
    });
    let addr: String = rx.recv().expect("server address");
    let mut c1 = Client::connect(&addr).unwrap();
    // 31 chars -> 33 prompt ids; last id 'A' (65) -> tokens 66..=89
    let mut stream = c1.stream(&"A".repeat(31), 24).unwrap();
    let mut events: Vec<Json> = Vec::new();
    while events.iter().filter(|e| e.get("event").as_str() == Some("token")).count() < 3 {
        events.push(stream.next().expect("stream ended early").unwrap());
    }
    // hot tenant on a second connection: priority 5 outranks the victim
    let mut c2 = Client::connect(&addr).unwrap();
    let hot: Vec<Json> = c2
        .stream_with(&"K".repeat(47), 8, vec![("priority", 5.into())])
        .unwrap()
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    assert_eq!(hot.last().unwrap().get("text").as_str(), Some("LMNOPQRS"));
    // drain the victim to its terminal line
    for ev in &mut stream {
        events.push(ev.unwrap());
    }
    let kinds: Vec<&str> = events.iter().map(|e| e.get("event").as_str().unwrap()).collect();
    assert!(kinds.contains(&"preempted"), "no preempted event: {kinds:?}");
    let fin = events.last().unwrap();
    assert_eq!(fin.get("event").as_str(), Some("finished"));
    assert_eq!(fin.get("finish").as_str(), Some("length"));
    // bit-identical stream across the preemption: 24 tokens, contiguous
    // indices, the full +1 chain in the summary
    assert_eq!(fin.get("text").as_str(), Some("BCDEFGHIJKLMNOPQRSTUVWXY"));
    let indices: Vec<usize> = events
        .iter()
        .filter(|e| e.get("event").as_str() == Some("token"))
        .map(|e| e.get("index").as_usize().unwrap())
        .collect();
    assert_eq!(indices, (0..24).collect::<Vec<usize>>());
    // stats surface the overload counters (and the deprecated always-zero
    // rebuild counters are gone from the payload)
    let s = c2.stats().unwrap();
    let stats = s.get("stats");
    let ov = stats.get("overload");
    assert_eq!(ov.get("policy").as_str(), Some("preempt_resume"));
    assert!(ov.get("preemptions").as_usize().unwrap() >= 1);
    assert!(ov.get("resumes").as_usize().unwrap() >= 1);
    assert_eq!(ov.get("preempted_queued").as_usize(), Some(0));
    assert_eq!(ov.get("deadline_met_tokens").as_usize(), Some(32));
    assert!(ov.get("goodput_tok_per_s").as_f64().unwrap() > 0.0);
    assert!(stats.get("kv_rebuilds").is_null());
    assert!(stats.get("regroups").is_null());
    assert!(stats.get("slot_copies").is_null());
    shut_down(&addr, h);
}

/// Satellite regression: cancelling (or disconnecting) a request while
/// it sits preempted must release every trace of it — no KV blocks, no
/// reservation-ledger entry, no queue state. The pool returns to its
/// pre-request baseline once the surviving request finishes.
#[test]
fn cancel_while_preempted_releases_ledger_and_pool() {
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        serve_with(
            "127.0.0.1:0",
            move |addr| {
                let _ = tx.send(addr);
            },
            move || {
                Ok(Scheduler::new(
                    MockEngine::new()
                        .with_pool_blocks(8)
                        .with_step_delay(Duration::from_millis(2)),
                    SparsityController::new(Mode::Dense),
                    SchedulerConfig { max_batch: 8, ..Default::default() },
                ))
            },
        )
    });
    let addr: String = rx.recv().expect("server address");
    let mut c1 = Client::connect(&addr).unwrap();
    let baseline = {
        let s = c1.stats().unwrap();
        let kv = s.get("stats").get("kv");
        assert_eq!(kv.get("blocks_in_use").as_usize(), Some(0));
        kv.get("blocks_available").as_usize().unwrap()
    };
    // victim: same geometry as preemption_rides_the_wire (33 ids + 24
    // new tokens), consumed until it is mid-decode
    let mut stream = c1.stream(&"A".repeat(31), 24).unwrap();
    let mut tokens = 0;
    while tokens < 3 {
        let ev = stream.next().expect("stream ended early").unwrap();
        if ev.get("event").as_str() == Some("token") {
            tokens += 1;
        }
    }
    // hot tenant forces the preemption
    let mut c2 = Client::connect(&addr).unwrap();
    let mut hot = c2
        .stream_with(&"K".repeat(47), 8, vec![("priority", 5.into())])
        .unwrap();
    // the moment the victim reports preempted, cancel it — the request
    // then holds only queue state, which the cancel must fully release
    let mut saw_preempted = false;
    loop {
        let ev = stream.next().expect("no terminal event").unwrap();
        match ev.get("event").as_str() {
            Some("preempted") => {
                saw_preempted = true;
                stream.cancel().unwrap();
            }
            Some("cancelled") => break,
            Some("finished") => panic!("victim finished despite cancel"),
            _ => {}
        }
    }
    assert!(saw_preempted, "victim was never preempted");
    // the survivor is untouched by the cancel
    let mut hot_fin = None;
    for ev in &mut hot {
        let ev = ev.unwrap();
        if ev.get("event").as_str() == Some("finished") {
            hot_fin = Some(ev);
        }
    }
    assert_eq!(hot_fin.expect("hot terminal").get("text").as_str(), Some("LMNOPQRS"));
    drop(hot);
    let s = c2.stats().unwrap();
    let stats = s.get("stats");
    let kv = stats.get("kv");
    assert_eq!(kv.get("blocks_in_use").as_usize(), Some(0), "kv leak: {kv}");
    assert_eq!(kv.get("blocks_available").as_usize(), Some(baseline));
    let ov = stats.get("overload");
    assert_eq!(ov.get("reserved_blocks").as_usize(), Some(0), "ledger leak: {ov}");
    assert_eq!(ov.get("preempted_queued").as_usize(), Some(0));
    assert_eq!(stats.get("cancelled_requests").as_usize(), Some(1));
    assert_eq!(stats.get("active").as_usize(), Some(0));
    shut_down(&addr, h);
}

/// Tentpole, observed over the wire: a poisoned request degrades its
/// polar step to dense (non-terminal "degraded" line), gets blamed by
/// the bisection search, and terminates with a structured
/// `engine_fault` — while the server survives and keeps serving.
#[test]
fn engine_fault_rides_the_wire_and_server_survives() {
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        serve_with(
            "127.0.0.1:0",
            move |addr| {
                let _ = tx.send(addr);
            },
            move || {
                // every decode batch carrying token 66 ('B', the first
                // token generated from prompt "A") fails persistently
                let inj = Arc::new(FaultInjector::new(FaultScript {
                    poison_token_range: Some((66, 70)),
                    ..Default::default()
                }));
                Ok(Scheduler::new(
                    MockEngine::new().with_faults(inj),
                    SparsityController::new(Mode::Polar { density: 0.5 }),
                    SchedulerConfig { max_batch: 8, compact: true, ..Default::default() },
                ))
            },
        )
    });
    let addr: String = rx.recv().expect("server address");
    let mut c = Client::connect(&addr).unwrap();
    let events: Vec<Json> = c
        .stream("A", 6)
        .unwrap()
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let kinds: Vec<&str> = events
        .iter()
        .map(|e| e.get("event").as_str().unwrap())
        .collect();
    assert_eq!(
        kinds,
        vec!["queued", "prefilled", "token", "degraded", "finished"],
        "events: {events:?}"
    );
    let fin = events.last().unwrap();
    assert_eq!(fin.get("finish").as_str(), Some("engine_fault"));
    // the one token emitted before the fault landed is kept
    assert_eq!(fin.get("text").as_str(), Some("B"));
    // the server survived blame isolation: a clean request still works
    let resp = c.request("K", 2).unwrap();
    assert_eq!(resp.get("text").as_str(), Some("LM"));
    assert_eq!(resp.get("finish").as_str(), Some("length"));
    // stats surface the fault counters
    let s = c.stats().unwrap();
    let f = s.get("stats").get("faults");
    assert_eq!(f.get("blame_bisections").as_usize(), Some(1), "{f}");
    assert_eq!(f.get("blamed_requests").as_usize(), Some(1));
    assert_eq!(f.get("degraded_steps").as_usize(), Some(1));
    shut_down(&addr, h);
}
