//! Cross-language router contract: the runtime router must rank heads
//! exactly like `python/compile/routers.py` does. The committed fixture
//! (`tests/fixtures/router_fixture.{npz,json}`, regenerate with
//! `python -m compile.routers --fixture ../rust/tests/fixtures`) carries
//! tiny attention-router weights, inputs and ground-truth labels plus the
//! python-side recall numbers in the `router_metrics.json` shape; the
//! rust side recomputes the recalls from the same npz.

use std::collections::HashMap;

use polar_sparsity::runtime::router::{recall_at_k, RouterBank};
use polar_sparsity::substrate::json::Json;
use xla::FromRawBytes;

fn fixture_path(name: &str) -> String {
    format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn rust_router_recall_matches_python_metrics() {
    let named = xla::Literal::read_npz(fixture_path("router_fixture.npz"), &())
        .expect("reading fixture npz");
    let map: HashMap<String, xla::Literal> = named.into_iter().collect();
    let dims = |n: &str| -> Vec<usize> {
        map[n]
            .array_shape()
            .unwrap()
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect()
    };
    let (l, d, g) = {
        let s = dims("ar_w");
        (s[0], s[1], s[2])
    };
    assert_eq!(dims("ar_b"), vec![l, g]);
    let n = dims("h")[1];
    assert_eq!(dims("h"), vec![l, n, d]);
    assert_eq!(dims("labels"), vec![l, n, g]);

    let ar_w = map["ar_w"].to_vec::<f32>().unwrap();
    let ar_b = map["ar_b"].to_vec::<f32>().unwrap();
    let h = map["h"].to_vec::<f32>().unwrap();
    let labels = map["labels"].to_vec::<f32>().unwrap();
    // embedding unused here: the fixture supplies router inputs directly
    let bank =
        RouterBank::new(l, d, g, g, 1, vec![0.0; d], vec![], ar_w, ar_b, None)
            .expect("fixture bank");

    let metrics = Json::parse(
        &std::fs::read_to_string(fixture_path("router_fixture.json")).unwrap(),
    )
    .expect("fixture json");
    let k = metrics.get("k").as_usize().expect("fixture k");
    let attn = metrics.get("attn").as_arr().expect("fixture attn metrics");
    assert_eq!(attn.len(), l);
    for (li, m) in attn.iter().enumerate() {
        assert_eq!(m.get("layer").as_usize(), Some(li));
        let want = m.get("recall_at_half").as_f64().expect("recall");
        let logits = bank.attn_logits(li, &h[li * n * d..(li + 1) * n * d], n);
        let got = recall_at_k(&logits, &labels[li * n * g..(li + 1) * n * g], g, k);
        assert!(
            (got - want).abs() < 1e-3,
            "layer {li}: rust recall {got} vs python {want}"
        );
        // the fixture is meaningful only if the router is imperfect but
        // far better than chance (k/G = 0.5 here)
        assert!(want > 0.6 && want < 1.0, "degenerate fixture recall {want}");
    }
}
