//! Integration tests over the real AOT artifacts (skipped with a notice
//! when `make artifacts` has not run).
//!
//! These exercise the full request path: manifest -> weights -> lazy HLO
//! compile -> prefill -> batched decode -> sampling -> completion, plus
//! dense-vs-polar numeric relationships and the PP/TP drivers.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use polar_sparsity::bench::accuracy::generate_one;
use polar_sparsity::coordinator::kv::pad_n;
use polar_sparsity::coordinator::{
    Mode, Request, Scheduler, SchedulerConfig, SparsityController,
};
use polar_sparsity::runtime::{
    split_pool_groups, split_pool_layers, BlockTables, Engine, Executor, KvCache, PagedKv,
    Tensor,
};
use polar_sparsity::tokenizer::Tokenizer;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new("artifacts/opt-tiny/manifest.json");
    if p.exists() {
        Some(PathBuf::from("artifacts"))
    } else {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        None
    }
}

fn engine(model: &str) -> Option<Engine> {
    let root = artifacts()?;
    let exec = Executor::load(&root.join(model)).expect("load artifacts");
    Some(Engine::new(Arc::new(exec)))
}

#[test]
fn prefill_then_decode_shapes_and_finiteness() {
    let Some(e) = engine("opt-tiny") else { return };
    let tok = Tokenizer::new();
    let ids = tok.encode_prompt("copy:ab=");
    let n = e.exec.manifest().seq_buckets[0];
    let out = e
        .prefill(
            &Tensor::i32(ids.clone(), vec![1, ids.len()]).unwrap(),
            &Tensor::i32(vec![ids.len() as i32], vec![1]).unwrap(),
            n,
        )
        .unwrap();
    let logits = out.logits.as_f32().unwrap();
    assert_eq!(logits.len(), e.exec.config().vocab);
    assert!(logits.iter().all(|x| x.is_finite()));

    let step = e
        .decode("dense", &[65], &[(ids.len() + 1) as i32], out.kv, None)
        .unwrap();
    assert!(step.logits.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn chunked_prefill_offsets_match_single_chunk() {
    // streaming a prompt as two chunks (6 tokens at offset 0, then 4 at
    // offset 6) must produce the same final logits as one chunk of 10
    let Some(e) = engine("opt-tiny") else { return };
    let cfg = e.exec.config().clone();
    let c = e.prefill_chunk_len();
    let n = e.exec.manifest().seq_buckets[0];
    let prompt: Vec<i32> = (0..10).map(|k| 65 + k).collect();
    let pad = |ids: &[i32]| {
        let mut t = vec![polar_sparsity::tokenizer::PAD; c];
        t[..ids.len()].copy_from_slice(ids);
        t
    };
    let fresh = || {
        KvCache::from_tensor(&Tensor::zeros_f32(cfg.kv_shape(1, n)), 1, n).unwrap()
    };
    let single = e
        .prefill_chunk(&pad(&prompt), &[10], &[0], fresh())
        .unwrap();
    let step1 = e
        .prefill_chunk(&pad(&prompt[..6]), &[6], &[0], fresh())
        .unwrap();
    let step2 = e
        .prefill_chunk(&pad(&prompt[6..]), &[4], &[6], step1.kv)
        .unwrap();
    let (a, b) = (
        single.logits.as_f32().unwrap(),
        step2.logits.as_f32().unwrap(),
    );
    let max_abs = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_abs < 1e-3, "chunked prefill diverges: {max_abs}");
}

#[test]
fn dense_and_polar_agree_at_full_density() {
    // llama-tiny applies no MLP sparsity, so polar at density 1.0 reduces
    // exactly to the dense path — logits must match tightly.
    let Some(e) = engine("llama-tiny") else { return };
    if e.exec.manifest().entries.get("decode_polar_d1000_b1_n128").is_none() {
        return;
    }
    let cfg = e.exec.config().clone();
    let kvt = Tensor::zeros_f32(cfg.kv_shape(1, 128));
    let lens = [6i32];
    let toks = [70i32];
    let a = e
        .decode("dense", &toks, &lens, KvCache::from_tensor(&kvt, 1, 128).unwrap(), None)
        .unwrap();
    let b = e
        .decode(
            "polar_d1000",
            &toks,
            &lens,
            KvCache::from_tensor(&kvt, 1, 128).unwrap(),
            None,
        )
        .unwrap();
    let (av, bv) = (a.logits.as_f32().unwrap(), b.logits.as_f32().unwrap());
    let max_abs = av
        .iter()
        .zip(bv)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_abs < 1e-3, "polar@1.0 diverges from dense: {max_abs}");

    // for the ReLU model, polar@1.0 keeps calibrated MLP top-k on: outputs
    // stay finite and close-but-not-identical (recall-99% semantics)
    let Some(eo) = engine("opt-tiny") else { return };
    let cfgo = eo.exec.config().clone();
    let kvo = Tensor::zeros_f32(cfgo.kv_shape(1, 128));
    let o = eo
        .decode("polar_d1000", &toks, &lens, KvCache::from_tensor(&kvo, 1, 128).unwrap(), None)
        .unwrap();
    assert!(o.logits.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn scheduler_serves_batch_with_real_engine() {
    let Some(e) = engine("opt-tiny") else { return };
    let ctl = SparsityController::new(Mode::Polar { density: 0.5 });
    ctl.validate(e.exec.manifest()).unwrap();
    let mut sched = Scheduler::new(e, ctl, SchedulerConfig::default());
    let tok = Tokenizer::new();
    for (i, p) in ["succ:a=", "succ:b=", "cmp:1,9=", "copy:ab=", "maj:aabab="]
        .iter()
        .enumerate()
    {
        sched.enqueue(
            Request::builder(tok.encode_prompt(p))
                .id(i as u64)
                .max_new_tokens(6)
                .build(),
        );
    }
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 5);
    for c in &done {
        assert!(!c.output_ids.is_empty());
        assert!(c.output_ids.len() <= 6);
    }
    assert!(sched.metrics.decode_steps > 0);
    assert!(sched.is_idle());
}

#[test]
fn sparse_modes_change_latency_not_sanity() {
    let Some(e) = engine("opt-tiny") else { return };
    let cfg = e.exec.config().clone();
    let kvt = Tensor::zeros_f32(cfg.kv_shape(4, 64));
    for tag in ["dense", "dejavu", "polar_d0500"] {
        let kv = KvCache::from_tensor(&kvt, 4, 64).unwrap();
        let out = e.decode(tag, &[65, 66, 67, 68], &[5, 6, 7, 8], kv, None).unwrap();
        let v = out.logits.as_f32().unwrap();
        assert_eq!(v.len(), 4 * cfg.vocab, "{tag}");
        assert!(v.iter().all(|x| x.is_finite()), "{tag}");
    }
}

#[test]
fn generate_one_produces_task_answer_shape() {
    let Some(e) = engine("opt-tiny") else { return };
    let tok = Tokenizer::new();
    let ids = tok.encode_prompt("succ:c=");
    let out = generate_one(&e, "dense", &ids, 6).unwrap();
    assert!(!out.is_empty() && out.len() <= 6);
}

/// Deterministic paged pool + identity block tables for one slot deep
/// into bucket `n` — shared by the sharded-driver tests below.
fn paged_fixture(e: &Engine, n: usize) -> (Tensor, BlockTables, [i32; 1], [i32; 1]) {
    let cfg = e.exec.config().clone();
    let (bs, pool_blocks) = e.kv_layout();
    let width = n / bs;
    let shape = cfg.kv_pool_shape(pool_blocks, bs);
    let elems: usize = shape.iter().product();
    let data: Vec<f32> = (0..elems).map(|i| ((i % 89) as f32 - 44.0) / 400.0).collect();
    let pool = Tensor::f32(data, shape).unwrap();
    let tables =
        BlockTables::new((0..width).map(|j| (1 + j) as i32).collect(), 1, width).unwrap();
    (pool, tables, [80i32], [30i32])
}

#[test]
fn pp2_paged_matches_single_device_decode() {
    let Some(e) = engine("opt-small") else { return };
    let cfg = e.exec.config().clone();
    let n = 256;
    let m = e.exec.manifest();
    if !m.entries.contains_key(&m.pp_stage_entry_name(0, "dense", 1, n)) {
        eprintln!("[skip] artifacts predate sharded paged entries; re-run `make artifacts`");
        return;
    }
    let (bs, pool_blocks) = e.kv_layout();
    let (pool, tables, toks, lens) = paged_fixture(&e, n);
    let single = e
        .decode_paged(
            "dense",
            &toks,
            &lens,
            &tables,
            PagedKv::from_tensor(&pool, pool_blocks, bs).unwrap(),
            None,
        )
        .unwrap();
    let (k0, k1) = split_pool_layers(&pool, cfg.n_layers / 2).unwrap();
    let (logits, _, _) = e
        .decode_pp2_paged(
            "dense",
            &toks,
            &lens,
            &tables,
            PagedKv::from_tensor(&k0, pool_blocks, bs).unwrap(),
            PagedKv::from_tensor(&k1, pool_blocks, bs).unwrap(),
            None,
        )
        .unwrap();
    let (a, b) = (single.logits.as_f32().unwrap(), logits.as_f32().unwrap());
    let max_abs = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_abs < 1e-3, "pp2 diverges: {max_abs}");
}

#[test]
fn tp2_paged_matches_single_device_decode() {
    let Some(e) = engine("opt-small") else { return };
    let n = 256;
    let m = e.exec.manifest();
    if !m.entries.contains_key(&m.tp_attn_entry_name(2, 0, "dense", 1, n)) {
        eprintln!("[skip] artifacts predate sharded paged entries; re-run `make artifacts`");
        return;
    }
    let (bs, pool_blocks) = e.kv_layout();
    let (pool, tables, toks, lens) = paged_fixture(&e, n);
    let single = e
        .decode_paged(
            "dense",
            &toks,
            &lens,
            &tables,
            PagedKv::from_tensor(&pool, pool_blocks, bs).unwrap(),
            None,
        )
        .unwrap();
    let pools: Vec<PagedKv> = split_pool_groups(&pool, 2)
        .unwrap()
        .iter()
        .map(|t| PagedKv::from_tensor(t, pool_blocks, bs).unwrap())
        .collect();
    let out = e
        .decode_tp_paged(2, "dense", "dense", &toks, &lens, &tables, pools, None)
        .unwrap();
    let (a, b) = (single.logits.as_f32().unwrap(), out.logits.as_f32().unwrap());
    let max_abs = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_abs < 1e-2, "tp2 diverges: {max_abs}");
}

#[test]
fn paged_decode_matches_contiguous_entry() {
    // pack a dense [L,2,1,G,64,dh] cache into pool blocks 1..=width and
    // decode through the fused paged entry: logits must match the
    // contiguous entry (same math; the table indexing is pure addressing).
    let Some(e) = engine("opt-tiny") else { return };
    if !e.exec.manifest().entries.contains_key("decode_dense_b1_n64_paged_fused") {
        eprintln!("[skip] artifacts predate fused paged entries; re-run `make artifacts`");
        return;
    }
    let cfg = e.exec.config().clone();
    let (bs, pool_blocks) = e.kv_layout();
    let n = 64usize;
    let width = n / bs;
    let (l_n, g_n, dh) = (cfg.n_layers, cfg.n_kv_heads, cfg.d_head);
    let mut data = vec![0f32; cfg.kv_elems(1, n)];
    for (i, x) in data.iter_mut().enumerate() {
        *x = ((i % 89) as f32 - 44.0) / 400.0;
    }
    let kvt = Tensor::f32(data, cfg.kv_shape(1, n)).unwrap();
    let mut pool_t = Tensor::zeros_f32(cfg.kv_pool_shape(pool_blocks, bs));
    {
        let src = kvt.as_f32().unwrap().to_vec();
        let dst = pool_t.as_f32_mut().unwrap();
        for l in 0..l_n {
            for c in 0..2 {
                for g in 0..g_n {
                    for j in 0..width {
                        for off in 0..bs {
                            let si = (((l * 2 + c) * g_n + g) * n + j * bs + off) * dh;
                            let di = ((((l * 2 + c) * pool_blocks + 1 + j) * g_n + g) * bs
                                + off)
                                * dh;
                            dst[di..di + dh].copy_from_slice(&src[si..si + dh]);
                        }
                    }
                }
            }
        }
    }
    let tables =
        BlockTables::new((0..width).map(|j| (1 + j) as i32).collect(), 1, width).unwrap();
    let toks = [90i32];
    let lens = [30i32];
    let contiguous = e
        .decode("dense", &toks, &lens, KvCache::from_tensor(&kvt, 1, n).unwrap(), None)
        .unwrap();
    let paged = e
        .decode_paged(
            "dense",
            &toks,
            &lens,
            &tables,
            PagedKv::from_tensor(&pool_t, pool_blocks, bs).unwrap(),
            None,
        )
        .unwrap();
    let (a, b) = (
        contiguous.logits.as_f32().unwrap(),
        paged.logits.as_f32().unwrap(),
    );
    let max_abs = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_abs < 1e-4, "paged decode diverges from contiguous: {max_abs}");
}

#[test]
fn kv_bucket_promotion_preserves_decode_results() {
    // decode at n=64, promote to n=128, decode again: lengths < 64 so the
    // padded region is masked and logits must match across buckets.
    let Some(e) = engine("opt-tiny") else { return };
    let cfg = e.exec.config().clone();
    let mut data = vec![0f32; cfg.kv_elems(1, 64)];
    for (i, x) in data.iter_mut().enumerate() {
        *x = ((i % 97) as f32 - 48.0) / 500.0;
    }
    let kvt = Tensor::f32(data, cfg.kv_shape(1, 64)).unwrap();
    let toks = [90i32];
    let lens = [30i32];
    let small = e
        .decode("dense", &toks, &lens, KvCache::from_tensor(&kvt, 1, 64).unwrap(), None)
        .unwrap();
    let big_t = pad_n(&kvt, 128).unwrap();
    let big = e
        .decode("dense", &toks, &lens, KvCache::from_tensor(&big_t, 1, 128).unwrap(), None)
        .unwrap();
    let (a, b) = (small.logits.as_f32().unwrap(), big.logits.as_f32().unwrap());
    let max_abs = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_abs < 1e-3, "bucket promotion changed logits: {max_abs}");
}
