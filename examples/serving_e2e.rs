//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Loads opt-tiny, replays an identical Poisson-arrival workload through
//! the continuous-batching scheduler under dense, DejaVu and Polar modes,
//! and reports throughput / TTFT / inter-token latency — the serving-paper
//! analogue of "load a small real model and serve batched requests".
//!
//!   cargo run --release --example serving_e2e [n_requests] [rate]

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use polar_sparsity::coordinator::{Mode, Scheduler, SchedulerConfig, SparsityController};
use polar_sparsity::runtime::{Engine, Executor};
use polar_sparsity::workload::{generate, WorkloadConfig};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40.0);

    let exec = Arc::new(Executor::load(std::path::Path::new("artifacts/opt-tiny"))?);
    let wl = WorkloadConfig {
        n_requests,
        arrival_rate: rate,
        prompt_len_min: 8,
        prompt_len_max: 48,
        max_new_tokens: 24,
        seed: 7,
        ..Default::default()
    };
    println!(
        "workload: {n_requests} requests, Poisson {rate}/s, prompts 8..48, 24 new tokens\n"
    );
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10} {:>9}",
        "mode", "tok/s", "itl p50", "ttft p50", "e2e p50", "steps"
    );
    for mode in [Mode::Dense, Mode::DejaVu, Mode::Polar { density: 0.5 }] {
        let engine = Engine::new(exec.clone());
        let ctl = SparsityController::new(mode);
        ctl.validate(engine.exec.manifest())?;
        // pre-compile all bucket variants so timings measure serving, not
        // first-touch JIT (the CUDA-graph capture analogue)
        engine.precompile(&ctl.decode_tag())?;
        let mut sched = Scheduler::new(
            engine,
            ctl,
            SchedulerConfig { max_batch: 16, compact: true },
        );
        // replay the same trace: requests arrive on their Poisson schedule
        let trace = generate(&wl);
        let t0 = Instant::now();
        let mut pending: std::collections::VecDeque<_> = trace.into();
        let mut completed = 0usize;
        while completed < n_requests {
            while let Some(front) = pending.front() {
                if t0.elapsed().as_secs_f64() >= front.at_s {
                    let mut tr = pending.pop_front().unwrap();
                    tr.request.enqueued_at = Instant::now();
                    sched.enqueue(tr.request);
                } else {
                    break;
                }
            }
            if sched.is_idle() {
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            completed += sched.step()?.len();
        }
        let m = &sched.metrics;
        println!(
            "{:<8} {:>10.1} {:>8.1}ms {:>8.1}ms {:>8.1}ms {:>9}",
            format!("{:?}", mode).split(' ').next().unwrap().to_lowercase(),
            m.decode_throughput(),
            m.itl.p50() * 1e3,
            m.ttft.p50() * 1e3,
            m.e2e.p50() * 1e3,
            m.decode_steps,
        );
    }
    println!("\n(record this run in EXPERIMENTS.md — serving e2e validation)");
    Ok(())
}
