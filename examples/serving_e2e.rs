//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Loads opt-tiny, replays an identical Poisson-arrival workload through
//! the continuous-batching scheduler under dense, DejaVu and Polar modes,
//! and reports throughput / TTFT / inter-token latency — measured from
//! the per-token event stream (bench::serving), exactly as a streaming
//! client observes them.
//!
//!   cargo run --release --example serving_e2e [n_requests] [rate]

use std::sync::Arc;

use anyhow::Result;
use polar_sparsity::bench::serving::replay;
use polar_sparsity::coordinator::{Mode, Scheduler, SchedulerConfig, SparsityController};
use polar_sparsity::runtime::{Engine, Executor};
use polar_sparsity::workload::{generate, WorkloadConfig};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40.0);

    let exec = Arc::new(Executor::load(std::path::Path::new("artifacts/opt-tiny"))?);
    let wl = WorkloadConfig {
        n_requests,
        arrival_rate: rate,
        prompt_len_min: 8,
        prompt_len_max: 48,
        max_new_tokens: 24,
        seed: 7,
        ..Default::default()
    };
    println!(
        "workload: {n_requests} requests, Poisson {rate}/s, prompts 8..48, 24 new tokens\n"
    );
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10} {:>9}",
        "mode", "tok/s", "itl p50", "ttft p50", "e2e p50", "steps"
    );
    for mode in [Mode::Dense, Mode::DejaVu, Mode::Polar { density: 0.5 }] {
        let engine = Engine::new(exec.clone());
        let ctl = SparsityController::new(mode);
        ctl.validate(engine.exec.manifest())?;
        // pre-compile all bucket variants so timings measure serving, not
        // first-touch JIT (the CUDA-graph capture analogue)
        engine.precompile(&ctl.decode_tag())?;
        let mut sched = Scheduler::new(
            engine,
            ctl,
            SchedulerConfig { max_batch: 16, compact: true, ..Default::default() },
        );
        // replay the same trace: requests arrive on their Poisson schedule
        // and every latency number comes from the event stream
        let run = replay(&mut sched, generate(&wl))?;
        assert_eq!(run.completions.len(), n_requests);
        println!(
            "{:<8} {:>10.1} {:>8.1}ms {:>8.1}ms {:>8.1}ms {:>9}",
            format!("{:?}", mode).split(' ').next().unwrap().to_lowercase(),
            sched.metrics.decode_throughput(),
            run.itl.p50() * 1e3,
            run.ttft.p50() * 1e3,
            run.e2e.p50() * 1e3,
            sched.metrics.decode_steps,
        );
    }
    println!("\n(record this run in EXPERIMENTS.md — serving e2e validation)");
    Ok(())
}
