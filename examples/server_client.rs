//! Server + client demo: starts the TCP JSON-lines server in-process on an
//! ephemeral port, drives it with concurrent blocking clients (so requests
//! batch), then shows the v2 protocol: a streaming client printing tokens
//! as they arrive, a mid-generation cancel, and the stats command.
//!
//!   cargo run --release --example server_client

use std::sync::mpsc::channel;

use anyhow::Result;
use polar_sparsity::coordinator::Mode;
use polar_sparsity::server::{serve, Client, ServerConfig};

fn main() -> Result<()> {
    let (addr_tx, addr_rx) = channel();
    let server = std::thread::spawn(move || {
        serve(
            ServerConfig {
                model_dir: "artifacts/opt-tiny".into(),
                addr: "127.0.0.1:0".to_string(),
                mode: Mode::Polar { density: 0.5 },
                max_batch: 8,
                prefill_chunk_tokens: 0,
            },
            move |addr| {
                let _ = addr_tx.send(addr);
            },
        )
    });
    let addr = addr_rx.recv()?;
    println!("server up on {addr}");

    // --- blocking clients in parallel: requests batch on the server -----
    let prompts = ["succ:a=", "succ:b=", "cmp:1,9=", "copy:xy=", "maj:aabab="];
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            let addr = addr.clone();
            let p = p.to_string();
            std::thread::spawn(move || -> Result<String> {
                let mut c = Client::connect(&addr)?;
                let resp = c.request(&p, 8)?;
                Ok(format!(
                    "{p:<12} -> {:?}  (ttft {:.0} ms)",
                    resp.get("text").as_str().unwrap_or("?"),
                    resp.get("ttft_ms").as_f64().unwrap_or(0.0)
                ))
            })
        })
        .collect();
    for h in handles {
        println!("{}", h.join().expect("client thread")?);
    }

    // --- streaming client: per-token events as they are emitted ---------
    let mut c = Client::connect(&addr)?;
    print!("stream succ:c=  -> ");
    for ev in c.stream("succ:c=", 8)? {
        let ev = ev?;
        match ev.get("event").as_str() {
            Some("token") => print!("{}", ev.get("text").as_str().unwrap_or("")),
            Some("finished") => println!(
                "  (finish {:?}, ttft {:.0} ms)",
                ev.get("finish").as_str().unwrap_or("?"),
                ev.get("ttft_ms").as_f64().unwrap_or(0.0)
            ),
            _ => {}
        }
    }

    // --- cancel mid-generation: token flow stops within one step --------
    let mut tokens_before_cancel = 0;
    let mut stream = c.stream("copy:abcabcabc=", 64)?;
    while let Some(ev) = stream.next() {
        let ev = ev?;
        match ev.get("event").as_str() {
            Some("token") => {
                tokens_before_cancel += 1;
                if tokens_before_cancel == 2 {
                    stream.cancel()?;
                }
            }
            Some("cancelled") => {
                println!(
                    "cancelled after {} tokens (partial {:?})",
                    tokens_before_cancel,
                    ev.get("text").as_str().unwrap_or("")
                );
            }
            _ => {}
        }
    }

    // --- engine metrics over the wire ------------------------------------
    let stats = c.stats()?;
    let s = stats.get("stats");
    println!(
        "stats: {} completed, {} cancelled, {} decode steps",
        s.get("completed_requests"),
        s.get("cancelled_requests"),
        s.get("decode_steps")
    );

    c.shutdown()?;
    server.join().expect("server thread")?;
    println!("server shut down cleanly");
    Ok(())
}
