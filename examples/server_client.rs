//! Server + client demo: starts the TCP JSON-lines server in-process on an
//! ephemeral port, drives it with concurrent clients (so requests batch),
//! then shuts it down.
//!
//!   cargo run --release --example server_client

use std::sync::mpsc::channel;

use anyhow::Result;
use polar_sparsity::coordinator::Mode;
use polar_sparsity::server::{serve, Client, ServerConfig};

fn main() -> Result<()> {
    let (addr_tx, addr_rx) = channel();
    let server = std::thread::spawn(move || {
        serve(
            ServerConfig {
                model_dir: "artifacts/opt-tiny".into(),
                addr: "127.0.0.1:0".to_string(),
                mode: Mode::Polar { density: 0.5 },
                max_batch: 8,
            },
            move |addr| {
                let _ = addr_tx.send(addr);
            },
        )
    });
    let addr = addr_rx.recv()?;
    println!("server up on {addr}");

    let prompts = ["succ:a=", "succ:b=", "cmp:1,9=", "copy:xy=", "maj:aabab="];
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            let addr = addr.clone();
            let p = p.to_string();
            std::thread::spawn(move || -> Result<String> {
                let mut c = Client::connect(&addr)?;
                let resp = c.request(&p, 8)?;
                Ok(format!(
                    "{p:<12} -> {:?}  (ttft {:.0} ms)",
                    resp.get("text").as_str().unwrap_or("?"),
                    resp.get("ttft_ms").as_f64().unwrap_or(0.0)
                ))
            })
        })
        .collect();
    for h in handles {
        println!("{}", h.join().expect("client thread")?);
    }

    Client::connect(&addr)?.shutdown()?;
    server.join().expect("server thread")?;
    println!("server shut down cleanly");
    Ok(())
}
