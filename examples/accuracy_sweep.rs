//! Accuracy-vs-density sweep on one model (the Fig 2a/Fig 4 protocol):
//! evaluates the zero-shot task suite at every AOT-compiled polar density
//! and prints the degradation curve with the critical threshold marked.
//!
//!   cargo run --release --example accuracy_sweep [model] [per_family]

use std::sync::Arc;

use anyhow::Result;
use polar_sparsity::bench::accuracy::{available_densities, eval_suite};
use polar_sparsity::coordinator::Mode;
use polar_sparsity::runtime::{Engine, Executor};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("opt-tiny");
    let per_family: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(10);

    let dir = std::path::PathBuf::from("artifacts").join(model);
    let exec = Arc::new(Executor::load(&dir)?);
    let engine = Engine::new(exec);
    let critical = engine.exec.config().critical_density;
    let suite = std::path::Path::new("artifacts/eval_tasks.jsonl");

    let dense = eval_suite(&engine, Mode::Dense, suite, per_family, 12)?;
    println!("{model}: dense average accuracy = {:.3}\n", dense.average);
    println!("{:>8} {:>10} {:>10}", "density", "accuracy", "delta");
    for d in available_densities(engine.exec.manifest()) {
        let s = eval_suite(&engine, Mode::Polar { density: d }, suite, per_family, 12)?;
        let mark = if (d - critical).abs() < 1e-9 { "  <- critical threshold" } else { "" };
        println!(
            "{d:>8.3} {:>10.3} {:>+10.3}{mark}",
            s.average,
            s.average - dense.average
        );
    }
    Ok(())
}
