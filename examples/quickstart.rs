//! Quickstart: load a model's AOT artifacts, run a few prompts through the
//! Polar-Sparsity engine and compare dense vs polar decoding.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;
use polar_sparsity::coordinator::{
    Mode, Request, Scheduler, SchedulerConfig, SparsityController,
};
use polar_sparsity::runtime::{Engine, Executor};
use polar_sparsity::tokenizer::Tokenizer;

fn main() -> Result<()> {
    let model_dir = std::path::Path::new("artifacts/opt-tiny");
    let exec = Arc::new(Executor::load(model_dir)?);
    let tok = Tokenizer::new();
    println!(
        "loaded {} ({} AOT entries)",
        exec.config().name,
        exec.manifest().entries.len()
    );

    for mode in [Mode::Dense, Mode::Polar { density: 0.5 }] {
        let engine = Engine::new(exec.clone());
        let ctl = SparsityController::new(mode);
        ctl.validate(engine.exec.manifest())?;
        engine.precompile(&ctl.decode_tag())?; // JIT out of the timed path
        let mut sched = Scheduler::new(engine, ctl, SchedulerConfig::default());
        for (i, prompt) in ["succ:c=", "cmp:3,8=", "copy:ab="].iter().enumerate() {
            sched.enqueue(
                Request::builder(tok.encode_prompt(prompt))
                    .id(i as u64)
                    .max_new_tokens(8)
                    .build(),
            );
        }
        let mut done = sched.run_to_completion()?;
        done.sort_by_key(|c| c.id);
        println!("\n--- mode {mode:?} ---");
        for c in &done {
            println!("  [{}] -> {:?}", c.id, tok.decode(&c.output_ids));
        }
        println!(
            "  decode throughput: {:.1} tok/s (p50 step {:.2} ms)",
            sched.metrics.decode_throughput(),
            sched.metrics.step_latency.p50() * 1e3
        );
    }
    Ok(())
}
